//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers the full JSON grammar we produce and consume: the AOT
//! `manifest.json`, run configuration files, and result summaries.
//! Numbers are kept as `f64` (integers round-trip exactly up to 2^53,
//! far beyond any byte count or batch size we handle).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports *which* key was missing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= 2f64.powi(53) {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------- parsing

    pub fn parse(input: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
        Self::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))
    }
}

// ------------------------------------------------------------ serialization

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

// ------------------------------------------------------------------ parser

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected {:?} at byte {}, found {:?}",
                          c as char, self.i,
                          self.peek().map(|b| b as char))
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}",
                                   other.map(|b| b as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()
            .map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5)
                                    .ok_or_else(|| anyhow::anyhow!(
                                        "truncated \\u escape"))?)?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => anyhow::bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let s = std::str::from_utf8(&self.b[self.i..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => anyhow::bail!("expected , or ] found {other:?}"),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => anyhow::bail!("expected , or }} found {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2]
                       .get("b").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"emoji":"héllo","nested":{"t":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn u64_guards() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn escapes_serialize() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn req_reports_key() {
        let j = Json::parse("{}").unwrap();
        let err = j.req("batch_sizes").unwrap_err().to_string();
        assert!(err.contains("batch_sizes"));
    }
}
