//! Calibrated discrete-event simulation (DES) support.
//!
//! The real serve loop executes XLA and sleeps through DMA throttles, so
//! a full 72-cell grid (3 patterns × 4 strategies × 3 SLAs × 2 modes)
//! costs hours of wall clock.  The DES path replays the *same*
//! scheduling code — `ModelQueues`, the `Strategy` impls, `SlaTracker`,
//! `RateEstimator` — against a cost table measured from the real system
//! ([`CostModel::measure`]), advancing a virtual clock instead of
//! executing.  Run it through
//! `engine::EngineBuilder::new(&cfg).des(&manifest, &costs)`;
//! EXPERIMENTS.md §Calibration cross-checks DES vs real cells.

pub mod calib;

pub use calib::CostModel;
