//! Calibrated discrete-event simulation (DES) of the serving system.
//!
//! The real serve loop executes XLA and sleeps through DMA throttles, so
//! a full 72-cell grid (3 patterns × 4 strategies × 3 SLAs × 2 modes)
//! costs hours of wall clock.  The DES replays the *same* scheduling
//! code — `ModelQueues`, the `Strategy` impls, `SlaTracker`,
//! `RateEstimator` — against a cost table measured from the real system
//! (`CostModel::measure`), advancing a virtual clock instead of
//! executing.  EXPERIMENTS.md §Calibration cross-checks DES vs real
//! cells.

pub mod calib;
pub mod des;

pub use calib::CostModel;
#[allow(deprecated)]
pub use des::simulate;
