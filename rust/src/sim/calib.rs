//! Cost-model calibration: measure the real system once, replay cheaply.
//!
//! `CostModel::measure` is simultaneously the paper's §III-D profiling:
//! model load/unload times per mode (Fig 3) and per-batch execution
//! times / throughput (Fig 4, OBS discovery).

use std::collections::BTreeMap;
use std::path::Path;

use crate::gpu::device::{GpuConfig, SimGpu};
use crate::gpu::CcMode;
use crate::runtime::Registry;
use crate::util::json::Json;
use crate::util::stopwatch::Stopwatch;

/// Measured costs for one model family.
#[derive(Debug, Clone, Default)]
pub struct ModelCosts {
    pub load_s_plain: f64,
    /// Serialized CC load (bounce chunks pay crypto + link in sequence).
    pub load_s_cc: f64,
    /// Pipelined CC load (`gpu::dma` chunk pipeline: steady-state
    /// `max(crypto, link)` per chunk instead of their sum).  Falls back
    /// to `load_s_cc` when unprofiled (pre-pipeline cost tables).
    pub load_s_cc_pipe: f64,
    /// Total modeled crypto work of one CC load (identical serialized
    /// or pipelined — the pipeline hides work, it doesn't remove it).
    pub load_crypto_s_cc: f64,
    /// Crypto seconds still exposed on a *pipelined* CC load (the fill
    /// chunk + any crypto overhang).  Serialized loads expose
    /// `load_crypto_s_cc` in full.
    pub load_crypto_exposed_s_cc_pipe: f64,
    pub unload_s: f64,
    /// artifact batch size -> mean execute seconds.
    pub exec_s_by_batch: BTreeMap<usize, f64>,
    /// Which batch sizes OOM'd their workspace at profile time.
    pub oom_batches: Vec<usize>,
    /// Max-throughput batch size among non-OOM batches (§III-D2 OBS).
    pub obs: usize,
}

impl ModelCosts {
    /// Exec time for `batch`, interpolating to the nearest profiled size.
    pub fn exec_s(&self, batch: usize) -> f64 {
        if let Some(&e) = self.exec_s_by_batch.get(&batch) {
            return e;
        }
        // nearest profiled batch at or above, else the largest below
        self.exec_s_by_batch.range(batch..).next()
            .or_else(|| self.exec_s_by_batch.range(..batch).next_back())
            .map(|(_, &e)| e)
            .unwrap_or(0.1)
    }

    pub fn load_s(&self, mode: CcMode) -> f64 {
        match mode {
            CcMode::On => self.load_s_cc,
            CcMode::Off => self.load_s_plain,
        }
    }

    /// Load seconds under an explicit pipeline setting.  Pre-pipeline
    /// cost tables (no profiled `load_s_cc_pipe`) fall back to the
    /// serialized figure, pricing the pipeline as a no-op rather than
    /// inventing a speedup.
    pub fn load_s_for(&self, mode: CcMode, pipelined: bool) -> f64 {
        match (mode, pipelined) {
            (CcMode::Off, _) => self.load_s_plain,
            (CcMode::On, false) => self.load_s_cc,
            (CcMode::On, true) => {
                if self.load_s_cc_pipe > 0.0 {
                    self.load_s_cc_pipe
                } else {
                    self.load_s_cc
                }
            }
        }
    }

    /// `(crypto_total_s, crypto_exposed_s)` of one load under the given
    /// mode/pipeline setting (both zero in No-CC).
    pub fn load_crypto_for(&self, mode: CcMode, pipelined: bool)
                           -> (f64, f64) {
        match mode {
            CcMode::Off => (0.0, 0.0),
            CcMode::On => {
                let exposed = if pipelined && self.load_s_cc_pipe > 0.0 {
                    self.load_crypto_exposed_s_cc_pipe
                } else {
                    self.load_crypto_s_cc
                };
                (self.load_crypto_s_cc, exposed)
            }
        }
    }

    /// Throughput (req/s) at a profiled batch size (Fig 4's y-axis).
    pub fn throughput_at(&self, batch: usize) -> f64 {
        let e = self.exec_s(batch);
        if e > 0.0 { batch as f64 / e } else { 0.0 }
    }
}

/// The full cost table.
#[derive(Debug, Clone, Default)]
pub struct CostModel {
    pub models: BTreeMap<String, ModelCosts>,
    /// Per-row request+response payload transfer seconds, by mode.
    pub io_s_per_row_plain: f64,
    pub io_s_per_row_cc: f64,
}

impl CostModel {
    /// True when any model lacks a profiled pipelined CC load — i.e.
    /// the table was cached before the pipeline existed, and pipelined
    /// runs would silently price as serialized.  Backends warn on this
    /// so a stale `cost_model.json` cannot fake a zero-benefit result.
    pub fn missing_pipeline_profile(&self) -> bool {
        self.models.values().any(|mc| mc.load_s_cc_pipe <= 0.0)
    }

    pub fn costs(&self, model: &str) -> anyhow::Result<&ModelCosts> {
        self.models.get(model).ok_or_else(|| anyhow::anyhow!(
            "no calibrated costs for model {model:?}"))
    }

    pub fn io_s_per_row(&self, mode: CcMode) -> f64 {
        match mode {
            CcMode::On => self.io_s_per_row_cc,
            CcMode::Off => self.io_s_per_row_plain,
        }
    }

    /// The synthetic cost table: fixed constants scaled by model size,
    /// never measured, so runs priced from it are bit-reproducible on
    /// any host.  One definition on purpose — the parity matrix, the
    /// pipeline/prefetch effect tests, the golden summaries and the CI
    /// lab smoke job all price these exact figures (so retuning a
    /// number here moves all of them together, and goldens then need
    /// `UPDATE_GOLDENS=1`).  Pipelined CC loads are cheaper than
    /// serialized ones with most of the crypto hidden, mirroring what
    /// `measure` observes on the real DMA pipeline.
    pub fn synthetic(manifest: &crate::runtime::Manifest) -> CostModel {
        let mut cm = CostModel {
            io_s_per_row_plain: 0.0004,
            io_s_per_row_cc: 0.0013,
            ..Default::default()
        };
        for f in &manifest.families {
            let size_factor = f.weights.total_bytes as f64 / 4e6;
            let mut mc = ModelCosts {
                load_s_plain: 0.30 * size_factor,
                load_s_cc: 0.85 * size_factor,
                load_s_cc_pipe: 0.50 * size_factor,
                load_crypto_s_cc: 0.42 * size_factor,
                load_crypto_exposed_s_cc_pipe: 0.07 * size_factor,
                unload_s: 0.006,
                obs: 8,
                ..Default::default()
            };
            for &b in &[1usize, 2, 4, 8] {
                mc.exec_s_by_batch.insert(
                    b, 0.07 + 0.011 * b as f64 * size_factor);
            }
            cm.models.insert(f.name.clone(), mc);
        }
        cm
    }

    /// Profile the real system: loads per mode (Fig 3), execution per
    /// batch size (Fig 4), unloads, and per-row I/O.  `reps` controls
    /// measurement repetitions.
    pub fn measure(registry: &Registry, base: &GpuConfig, reps: usize)
                   -> anyhow::Result<CostModel> {
        assert!(reps >= 1);
        let mut cm = CostModel::default();

        // one device per mode for load profiling; the CC device is
        // forced serialized so `load_s_cc` always means the worst-case
        // bounce path, whatever the base config says
        let mut gpus = Vec::new();
        for mode in [CcMode::Off, CcMode::On] {
            gpus.push((mode, SimGpu::new(GpuConfig {
                mode, pipeline_depth: 0, ..base.clone()
            })?));
        }
        // plus one pipelined CC device: same budget split, overlapped
        let mut pipe_gpu = SimGpu::new(GpuConfig {
            mode: CcMode::On,
            pipeline_depth: base.pipeline_depth.max(2),
            ..base.clone()
        })?;

        for name in registry.names() {
            let entry = registry.entry(&name)?;
            let mut mc = ModelCosts::default();

            // ---- load/unload per mode (Fig 3) ----
            for (mode, gpu) in gpus.iter_mut() {
                let mut total = 0.0;
                let mut crypto_total = 0.0;
                let mut unload_total = 0.0;
                for _ in 0..reps {
                    let (buf, rep) = gpu.upload(&entry.weights.raw)?;
                    total += rep.elapsed.as_secs_f64();
                    crypto_total += rep.crypto_total.as_secs_f64();
                    unload_total += gpu.unload(buf).as_secs_f64();
                }
                let mean = total / reps as f64;
                match mode {
                    CcMode::Off => mc.load_s_plain = mean,
                    CcMode::On => {
                        mc.load_s_cc = mean;
                        mc.load_crypto_s_cc = crypto_total / reps as f64;
                    }
                }
                mc.unload_s = unload_total / (reps as f64 * 2.0)
                    + mc.unload_s / 2.0; // average across both modes
            }

            // ---- pipelined CC load (the overlap the DES must price) ----
            {
                let mut total = 0.0;
                let mut exposed_total = 0.0;
                for _ in 0..reps {
                    let (buf, rep) = pipe_gpu.upload(&entry.weights.raw)?;
                    total += rep.elapsed.as_secs_f64();
                    exposed_total += rep.crypto_exposed.as_secs_f64();
                    pipe_gpu.unload(buf);
                }
                mc.load_s_cc_pipe = total / reps as f64;
                mc.load_crypto_exposed_s_cc_pipe =
                    exposed_total / reps as f64;
            }

            // ---- execution per batch size (Fig 4) ----
            // memory check against the device model: weights + workspace
            let capacity = base.hbm_capacity;
            for &b in entry.compiled_batch_sizes().iter() {
                let need = entry.spec.weight_bytes()
                    + entry.spec.batch_workspace_bytes(b);
                if need > capacity {
                    mc.oom_batches.push(b);
                    continue;
                }
                let rows: Vec<Vec<i32>> = (0..b)
                    .map(|i| {
                        (0..entry.spec.prompt_len)
                            .map(|j| ((i * 31 + j * 7) % entry.spec.vocab)
                                 as i32)
                            .collect()
                    }).collect();
                // warmup once, then measure
                registry.execute(&name, &rows)?;
                let mut total = 0.0;
                for _ in 0..reps {
                    let sw = Stopwatch::start();
                    registry.execute(&name, &rows)?;
                    total += sw.elapsed_s();
                }
                mc.exec_s_by_batch.insert(b, total / reps as f64);
            }
            anyhow::ensure!(!mc.exec_s_by_batch.is_empty(),
                            "all batch sizes OOM for {name}");

            // OBS: max throughput among profiled batches
            mc.obs = mc.exec_s_by_batch.iter()
                .max_by(|a, b| {
                    let ta = *a.0 as f64 / a.1;
                    let tb = *b.0 as f64 / b.1;
                    ta.partial_cmp(&tb).unwrap()
                })
                .map(|(&b, _)| b).unwrap();

            cm.models.insert(name, mc);
        }

        // ---- per-row I/O (prompt in + tokens out) ----
        let spec = &registry.entry(&registry.names()[0])?.spec;
        let row_bytes = 4 * (spec.prompt_len + spec.decode_len);
        let payload = vec![0u8; row_bytes];
        for (mode, gpu) in gpus.iter_mut() {
            let mut total = 0.0;
            for _ in 0..reps.max(3) {
                let rep = gpu.io_transfer(
                    crate::gpu::dma::Dir::HostToDevice, &payload)?;
                total += rep.elapsed.as_secs_f64();
            }
            let mean = total / reps.max(3) as f64;
            match mode {
                CcMode::Off => cm.io_s_per_row_plain = mean,
                CcMode::On => cm.io_s_per_row_cc = mean,
            }
        }
        Ok(cm)
    }

    // ------------------------------------------------------ persistence

    pub fn to_json(&self) -> Json {
        let models = self.models.iter().map(|(name, mc)| {
            (name.clone(), Json::obj(vec![
                ("load_s_plain", Json::num(mc.load_s_plain)),
                ("load_s_cc", Json::num(mc.load_s_cc)),
                ("load_s_cc_pipe", Json::num(mc.load_s_cc_pipe)),
                ("load_crypto_s_cc", Json::num(mc.load_crypto_s_cc)),
                ("load_crypto_exposed_s_cc_pipe",
                 Json::num(mc.load_crypto_exposed_s_cc_pipe)),
                ("unload_s", Json::num(mc.unload_s)),
                ("obs", Json::num(mc.obs as f64)),
                ("oom_batches", Json::Arr(mc.oom_batches.iter()
                    .map(|&b| Json::num(b as f64)).collect())),
                ("exec_s_by_batch", Json::Obj(mc.exec_s_by_batch.iter()
                    .map(|(&b, &e)| (b.to_string(), Json::num(e)))
                    .collect())),
            ]))
        }).collect();
        Json::obj(vec![
            ("io_s_per_row_plain", Json::num(self.io_s_per_row_plain)),
            ("io_s_per_row_cc", Json::num(self.io_s_per_row_cc)),
            ("models", Json::Obj(models)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<CostModel> {
        let mut cm = CostModel {
            io_s_per_row_plain: j.req("io_s_per_row_plain")?.as_f64()
                .unwrap_or(0.0),
            io_s_per_row_cc: j.req("io_s_per_row_cc")?.as_f64()
                .unwrap_or(0.0),
            ..Default::default()
        };
        for (name, mj) in j.req("models")?.as_obj()
            .ok_or_else(|| anyhow::anyhow!("models not an object"))?
        {
            let mut mc = ModelCosts {
                load_s_plain: mj.req("load_s_plain")?.as_f64().unwrap_or(0.0),
                load_s_cc: mj.req("load_s_cc")?.as_f64().unwrap_or(0.0),
                // pipeline fields are optional: pre-pipeline cost
                // tables load with the serialized fallbacks
                load_s_cc_pipe: mj.get("load_s_cc_pipe")
                    .and_then(|v| v.as_f64()).unwrap_or(0.0),
                load_crypto_s_cc: mj.get("load_crypto_s_cc")
                    .and_then(|v| v.as_f64()).unwrap_or(0.0),
                load_crypto_exposed_s_cc_pipe:
                    mj.get("load_crypto_exposed_s_cc_pipe")
                        .and_then(|v| v.as_f64()).unwrap_or(0.0),
                unload_s: mj.req("unload_s")?.as_f64().unwrap_or(0.0),
                obs: mj.req("obs")?.as_usize().unwrap_or(1),
                ..Default::default()
            };
            if let Some(arr) = mj.req("oom_batches")?.as_arr() {
                mc.oom_batches = arr.iter()
                    .filter_map(|b| b.as_usize()).collect();
            }
            for (b, e) in mj.req("exec_s_by_batch")?.as_obj()
                .ok_or_else(|| anyhow::anyhow!("exec_s not an object"))?
            {
                mc.exec_s_by_batch.insert(
                    b.parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("bad batch {b:?}"))?,
                    e.as_f64().unwrap_or(0.0));
            }
            cm.models.insert(name.clone(), mc);
        }
        Ok(cm)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<CostModel> {
        Self::from_json(&Json::parse_file(path)?)
    }

    /// Load a cached cost model, or (compile the registry and) measure
    /// one and cache it.  Shared by the figure benches and examples so
    /// the expensive profiling happens once per checkout.
    pub fn load_or_measure(artifacts_dir: &Path, cache_path: &Path,
                           base: &GpuConfig, reps: usize)
                           -> anyhow::Result<CostModel> {
        if cache_path.exists() {
            return Self::load(cache_path);
        }
        let manifest = crate::runtime::Manifest::load(artifacts_dir)?;
        let registry = crate::runtime::Registry::load(&manifest, &[], &[])?;
        let cm = Self::measure(&registry, base, reps)?;
        cm.save(cache_path)?;
        Ok(cm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CostModel {
        let mut cm = CostModel {
            io_s_per_row_plain: 0.001,
            io_s_per_row_cc: 0.003,
            ..Default::default()
        };
        let mut mc = ModelCosts {
            load_s_plain: 0.3,
            load_s_cc: 0.9,
            load_s_cc_pipe: 0.5,
            load_crypto_s_cc: 0.45,
            load_crypto_exposed_s_cc_pipe: 0.05,
            unload_s: 0.006,
            obs: 8,
            ..Default::default()
        };
        mc.exec_s_by_batch.insert(1, 0.05);
        mc.exec_s_by_batch.insert(8, 0.2);
        mc.oom_batches.push(32);
        cm.models.insert("llama-sim".into(), mc);
        cm
    }

    #[test]
    fn json_roundtrip() {
        let cm = sample();
        let j = cm.to_json();
        let back = CostModel::from_json(&j).unwrap();
        let a = back.costs("llama-sim").unwrap();
        assert_eq!(a.obs, 8);
        assert_eq!(a.oom_batches, vec![32]);
        assert!((a.load_s_cc - 0.9).abs() < 1e-12);
        assert!((a.load_s_cc_pipe - 0.5).abs() < 1e-12);
        assert!((a.load_crypto_s_cc - 0.45).abs() < 1e-12);
        assert!((a.load_crypto_exposed_s_cc_pipe - 0.05).abs() < 1e-12);
        assert!((a.exec_s(8) - 0.2).abs() < 1e-12);
        assert!((back.io_s_per_row_cc - 0.003).abs() < 1e-12);
    }

    #[test]
    fn pre_pipeline_cost_tables_still_load() {
        // strip the pipeline fields from the JSON: a cached cost model
        // from before the pipeline existed must parse, pricing the
        // pipeline as a no-op
        let cm = sample();
        let j = cm.to_json();
        let mut obj = j.as_obj().unwrap().clone();
        let models = obj.get_mut("models").unwrap();
        if let crate::util::json::Json::Obj(m) = models {
            for (_, mj) in m.iter_mut() {
                if let crate::util::json::Json::Obj(fields) = mj {
                    fields.remove("load_s_cc_pipe");
                    fields.remove("load_crypto_s_cc");
                    fields.remove("load_crypto_exposed_s_cc_pipe");
                }
            }
        }
        let back =
            CostModel::from_json(&crate::util::json::Json::Obj(obj))
                .unwrap();
        let a = back.costs("llama-sim").unwrap();
        assert_eq!(a.load_s_cc_pipe, 0.0);
        assert!((a.load_s_for(CcMode::On, true) - 0.9).abs() < 1e-12,
                "missing pipe figure falls back to serialized");
        assert_eq!(a.load_crypto_for(CcMode::On, true), (0.0, 0.0));
    }

    #[test]
    fn load_selectors_respect_mode_and_pipeline() {
        let cm = sample();
        let mc = cm.costs("llama-sim").unwrap();
        assert_eq!(mc.load_s_for(CcMode::Off, true), 0.3,
                   "pipeline never changes No-CC");
        assert_eq!(mc.load_s_for(CcMode::On, false), 0.9);
        assert_eq!(mc.load_s_for(CcMode::On, true), 0.5);
        assert_eq!(mc.load_crypto_for(CcMode::Off, false), (0.0, 0.0));
        assert_eq!(mc.load_crypto_for(CcMode::On, false), (0.45, 0.45),
                   "serialized exposes all crypto");
        assert_eq!(mc.load_crypto_for(CcMode::On, true), (0.45, 0.05),
                   "pipelined hides most crypto");
    }

    #[test]
    fn exec_interpolates_to_nearest() {
        let cm = sample();
        let mc = cm.costs("llama-sim").unwrap();
        assert_eq!(mc.exec_s(4), 0.2, "rounds up to batch 8");
        assert_eq!(mc.exec_s(100), 0.2, "clamps down to largest");
        assert_eq!(mc.exec_s(1), 0.05);
    }

    #[test]
    fn throughput_and_mode_selectors() {
        let cm = sample();
        let mc = cm.costs("llama-sim").unwrap();
        assert!((mc.throughput_at(8) - 40.0).abs() < 1e-9);
        assert_eq!(mc.load_s(CcMode::On), 0.9);
        assert_eq!(mc.load_s(CcMode::Off), 0.3);
        assert!(cm.costs("missing").is_err());
    }
}
