//! The discrete-event entry point — a thin shim over the [`Engine`].
//!
//! Identical decision logic to the real serve path by construction:
//! the engine runs one loop for both time domains, and the DES is just
//! `DesBackend` + `VirtualClock` (costs from the calibrated
//! [`CostModel`], virtual time instead of execution).  This module
//! keeps the historical `sim::simulate` API; new code should use
//! [`EngineBuilder`](crate::engine::EngineBuilder) directly.
//!
//! [`Engine`]: crate::engine::Engine

use crate::config::RunConfig;
use crate::engine::{EngineBuilder, RunSummary};
use crate::runtime::Manifest;
use crate::sim::calib::CostModel;

/// Simulate one grid cell. Returns the same `RunSummary` the real
/// serve loop produces (with virtual time standing in for wall time).
#[deprecated(
    since = "0.2.0",
    note = "use engine::EngineBuilder::new(cfg).des(manifest, costs)?.run()"
)]
pub fn simulate(cfg: &RunConfig, manifest: &Manifest, costs: &CostModel)
                -> anyhow::Result<RunSummary> {
    let (summary, _recorder) =
        EngineBuilder::new(cfg).des(manifest, costs)?.run()?;
    Ok(summary)
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::sim::calib::ModelCosts;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn toy_costs(manifest: &Manifest) -> CostModel {
        let mut cm = CostModel {
            io_s_per_row_plain: 0.0005,
            io_s_per_row_cc: 0.0015,
            ..Default::default()
        };
        for f in &manifest.families {
            let size_factor = f.weights.total_bytes as f64 / 4e6;
            let mut mc = ModelCosts {
                load_s_plain: 0.35 * size_factor,
                load_s_cc: 1.0 * size_factor,
                unload_s: 0.006,
                obs: 16,
                ..Default::default()
            };
            for &b in &[1usize, 2, 4, 8, 16, 32] {
                mc.exec_s_by_batch.insert(
                    b, 0.08 + 0.012 * b as f64 * size_factor);
            }
            cm.models.insert(f.name.clone(), mc);
        }
        cm
    }

    fn base_cfg() -> RunConfig {
        RunConfig {
            duration_s: 120.0,
            drain_s: 10.0,
            mean_rps: 4.0,
            ..Default::default()
        }
    }

    #[test]
    fn simulation_completes_requests() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        let s = simulate(&base_cfg(), &m, &costs).unwrap();
        assert!(s.generated > 300, "generated {}", s.generated);
        assert!(s.completed > 0);
        assert!(s.completed + 50 > s.generated / 2,
                "too few completed: {}/{}", s.completed, s.generated);
        assert!(s.gpu_util > 0.0 && s.gpu_util < 1.0);
        assert!(s.swap_count > 1);
    }

    #[test]
    fn cc_mode_is_slower_end_to_end() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        let mut cc = base_cfg();
        cc.set("mode", "cc").unwrap();
        let s_cc = simulate(&cc, &m, &costs).unwrap();
        let s_plain = simulate(&base_cfg(), &m, &costs).unwrap();
        assert!(s_cc.latency_mean_s > s_plain.latency_mean_s,
                "cc {} <= plain {}", s_cc.latency_mean_s,
                s_plain.latency_mean_s);
        assert!(s_cc.sla_attainment <= s_plain.sla_attainment + 0.05);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        let a = simulate(&base_cfg(), &m, &costs).unwrap();
        let b = simulate(&base_cfg(), &m, &costs).unwrap();
        assert_eq!(a.completed, b.completed);
        assert!((a.latency_mean_s - b.latency_mean_s).abs() < 1e-12);
    }

    #[test]
    fn all_strategies_run() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        for name in crate::coordinator::STRATEGY_NAMES {
            let mut cfg = base_cfg();
            cfg.strategy = name.to_string();
            let s = simulate(&cfg, &m, &costs).unwrap();
            assert!(s.completed > 0, "{name} completed nothing");
        }
    }

    #[test]
    fn accounting_identity_holds() {
        // generated == completed + unserved (via sla totals)
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        let s = simulate(&base_cfg(), &m, &costs).unwrap();
        assert!(s.sla_met <= s.completed);
        assert!(s.completed <= s.generated);
    }
}
