//! The discrete-event serve loop: identical decision logic to
//! `coordinator::server::serve`, but time is virtual and costs come from
//! the calibrated [`CostModel`].
//!
//! Because strategies are pure functions over `SchedContext`, the DES
//! and the real server literally share the scheduling code — the DES
//! only replaces (a) the clock, (b) the swap/execute costs, and (c) the
//! device occupancy accounting.

use crate::config::RunConfig;
use crate::coordinator::queues::ModelQueues;
use crate::coordinator::rate::RateEstimator;
use crate::coordinator::request::{CompletedRequest, Request};
use crate::coordinator::server::RunSummary;
use crate::coordinator::sla::SlaTracker;
use crate::coordinator::strategy::{strategy_by_name, Decision, ModelView,
                                   SchedContext};
use crate::metrics::hist::Histogram;
use crate::runtime::Manifest;
use crate::sim::calib::CostModel;
use crate::traffic::pattern_by_name;
use crate::traffic::rng::Pcg64;

/// Simulate one grid cell. Returns the same `RunSummary` the real serve
/// loop produces (with virtual time standing in for wall time).
pub fn simulate(cfg: &RunConfig, manifest: &Manifest, costs: &CostModel)
                -> anyhow::Result<RunSummary> {
    cfg.validate()?;
    let strategy = strategy_by_name(&cfg.strategy)?;
    let models: Vec<String> = if cfg.models.is_empty() {
        manifest.family_names()
    } else {
        cfg.models.clone()
    };
    for m in &models {
        manifest.family(m)?;
        costs.costs(m)?;
    }
    let mode = cfg.mode;

    // ---------------- arrival schedule (same generator as serve) -------
    let mut rng = Pcg64::new(cfg.seed);
    let pattern = pattern_by_name(&cfg.pattern)?;
    let arrivals = pattern.generate(cfg.duration_s, cfg.mean_rps, &models,
                                    &mut rng);
    let generated = arrivals.len() as u64;
    let mut pending: std::collections::VecDeque<Request> =
        arrivals.iter().enumerate().map(|(i, a)| Request {
            id: i as u64,
            model: a.model.clone(),
            tokens: Vec::new(), // content never affects the DES
            arrival_s: a.at_s,
        }).collect();

    // ---------------- virtual-time loop --------------------------------
    let mut now = 0.0f64;
    let mut queues = ModelQueues::new();
    let mut rates = RateEstimator::default();
    let mut sla = SlaTracker::new(cfg.sla_s);
    let mut hist = Histogram::new();
    let mut resident: Option<String> = None;

    let mut completed = 0u64;
    let mut swap_count = 0u64;
    let mut total_load_s = 0.0;
    let mut total_unload_s = 0.0;
    let mut exec_busy_s = 0.0;
    let mut last_complete_s = 0.0f64;
    // The paper's methodology: generation stops at `duration_s`, but the
    // system keeps draining the backlog; total runtime extends to the
    // last dispatched response (this is where CC's lower throughput and
    // GPU utilization come from).  `drain_s` is a safety cap only.
    let hard_stop = cfg.duration_s + cfg.drain_s;

    loop {
        // ingest everything due by `now`
        while pending.front().map(|r| r.arrival_s <= now).unwrap_or(false) {
            let r = pending.pop_front().unwrap();
            rates.on_arrival(&r.model, r.arrival_s);
            queues.push(r);
        }
        // SLA expiry: overdue queued requests are unfulfilled (§III-C3)
        let expired = queues.expire(now, cfg.sla_s);
        sla.on_unserved(expired.len() as u64);
        if now >= hard_stop {
            break;
        }
        if pending.is_empty() && queues.is_empty() {
            break;
        }

        let views: Vec<ModelView> = queues.nonempty_models().iter()
            .map(|m| {
                let mc = costs.costs(m).unwrap();
                ModelView {
                    model: m.to_string(),
                    len: queues.len(m),
                    oldest_wait_s: queues.head_arrival_s(m)
                        .map(|a| (now - a).max(0.0)).unwrap_or(0.0),
                    obs: mc.obs,
                    rate_rps: rates.rate_rps(m, now),
                    est_load_s: mc.load_s(mode),
                    est_exec_s: mc.exec_s(mc.obs),
                }
            }).collect();
        let ctx = SchedContext {
            now_s: now,
            resident: resident.clone(),
            queues: views,
            sla_s: cfg.sla_s,
            timeout_s: cfg.timeout_s(),
        };

        match strategy.decide(&ctx) {
            Decision::Wait => {
                // jump to the next *future* actionable instant: the next
                // arrival or the earliest not-yet-expired timer.  Timers
                // already in the past are irrelevant — if the strategy
                // cared about them it would have returned Process.
                let next_arrival = pending.front().map(|r| r.arrival_s)
                    .unwrap_or(f64::INFINITY);
                let next_timer = queues.nonempty_models().iter()
                    .filter_map(|m| queues.head_arrival_s(m))
                    .flat_map(|a| [a + cfg.timeout_s(), a + cfg.sla_s])
                    .filter(|&t| t > now)
                    .fold(f64::INFINITY, f64::min);
                let next = next_arrival.min(next_timer);
                if !next.is_finite() || next <= now {
                    // no future event can change the decision (e.g.
                    // best-batch stranding a sub-OBS remainder): done
                    break;
                }
                now = next.min(hard_stop);
            }
            Decision::Process { model, take } => {
                let mc = costs.costs(&model)?;
                // swap if needed
                if resident.as_deref() != Some(model.as_str()) {
                    if resident.is_some() {
                        now += mc.unload_s;
                        total_unload_s += mc.unload_s;
                    }
                    let load = mc.load_s(mode);
                    now += load;
                    total_load_s += load;
                    swap_count += 1;
                    resident = Some(model.clone());
                }
                // batch assembly
                let reqs = queues.pop_n(&model, take.max(1));
                if reqs.is_empty() {
                    continue;
                }
                let spec = manifest.family(&model)?;
                let artifact_batch = spec.batch_size_at_least(reqs.len());
                let exec_s = mc.exec_s(artifact_batch);
                let io_s = costs.io_s_per_row(mode) * reqs.len() as f64;

                let exec_start_s = now;
                now += exec_s + io_s;
                exec_busy_s += exec_s;

                for r in &reqs {
                    let c = CompletedRequest {
                        id: r.id,
                        model: r.model.clone(),
                        arrival_s: r.arrival_s,
                        exec_start_s,
                        complete_s: now,
                        batch: artifact_batch,
                        batch_rows: reqs.len(),
                        caused_swap: false,
                    };
                    sla.on_complete(&c);
                    hist.record(c.latency_s());
                    completed += 1;
                }
                last_complete_s = now;
            }
        }
    }

    // runtime = generation window extended by the drain tail (paper:
    // total runtime covers every processed request)
    let runtime_s = last_complete_s.max(cfg.duration_s).max(1e-9);
    let unserved = queues.drain_all().len() as u64
        + pending.iter().filter(|r| r.arrival_s < cfg.duration_s).count()
            as u64;
    sla.on_unserved(unserved);

    Ok(RunSummary {
        label: cfg.label.clone(),
        mode: mode.as_str().to_string(),
        pattern: cfg.pattern.clone(),
        strategy: cfg.strategy.clone(),
        sla_s: cfg.sla_s,
        mean_rps: cfg.mean_rps,
        duration_s: cfg.duration_s,
        runtime_s,
        generated,
        completed,
        sla_met: sla.met(),
        sla_attainment: sla.attainment(),
        latency_mean_s: hist.mean(),
        latency_p50_s: hist.quantile(0.5),
        latency_p90_s: hist.quantile(0.9),
        latency_p99_s: hist.quantile(0.99),
        latency_max_s: hist.max(),
        throughput_rps: completed as f64 / runtime_s,
        processing_rate_rps: if exec_busy_s > 0.0 {
            completed as f64 / exec_busy_s
        } else {
            0.0
        },
        gpu_util: (exec_busy_s / runtime_s).min(1.0),
        swap_count,
        total_load_s,
        total_unload_s,
        total_exec_s: exec_busy_s,
        total_crypto_s: 0.0,
        mean_load_s: if swap_count > 0 {
            total_load_s / swap_count as f64
        } else {
            0.0
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::calib::ModelCosts;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn toy_costs(manifest: &Manifest) -> CostModel {
        let mut cm = CostModel {
            io_s_per_row_plain: 0.0005,
            io_s_per_row_cc: 0.0015,
            ..Default::default()
        };
        for f in &manifest.families {
            let size_factor = f.weights.total_bytes as f64 / 4e6;
            let mut mc = ModelCosts {
                load_s_plain: 0.35 * size_factor,
                load_s_cc: 1.0 * size_factor,
                unload_s: 0.006,
                obs: 16,
                ..Default::default()
            };
            for &b in &[1usize, 2, 4, 8, 16, 32] {
                mc.exec_s_by_batch.insert(
                    b, 0.08 + 0.012 * b as f64 * size_factor);
            }
            cm.models.insert(f.name.clone(), mc);
        }
        cm
    }

    fn base_cfg() -> RunConfig {
        RunConfig {
            duration_s: 120.0,
            drain_s: 10.0,
            mean_rps: 4.0,
            ..Default::default()
        }
    }

    #[test]
    fn simulation_completes_requests() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        let s = simulate(&base_cfg(), &m, &costs).unwrap();
        assert!(s.generated > 300, "generated {}", s.generated);
        assert!(s.completed > 0);
        assert!(s.completed + 50 > s.generated / 2,
                "too few completed: {}/{}", s.completed, s.generated);
        assert!(s.gpu_util > 0.0 && s.gpu_util < 1.0);
        assert!(s.swap_count > 1);
    }

    #[test]
    fn cc_mode_is_slower_end_to_end() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        let mut cc = base_cfg();
        cc.set("mode", "cc").unwrap();
        let s_cc = simulate(&cc, &m, &costs).unwrap();
        let s_plain = simulate(&base_cfg(), &m, &costs).unwrap();
        assert!(s_cc.latency_mean_s > s_plain.latency_mean_s,
                "cc {} <= plain {}", s_cc.latency_mean_s,
                s_plain.latency_mean_s);
        assert!(s_cc.sla_attainment <= s_plain.sla_attainment + 0.05);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        let a = simulate(&base_cfg(), &m, &costs).unwrap();
        let b = simulate(&base_cfg(), &m, &costs).unwrap();
        assert_eq!(a.completed, b.completed);
        assert!((a.latency_mean_s - b.latency_mean_s).abs() < 1e-12);
    }

    #[test]
    fn all_strategies_run() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        for name in crate::coordinator::STRATEGY_NAMES {
            let mut cfg = base_cfg();
            cfg.strategy = name.to_string();
            let s = simulate(&cfg, &m, &costs).unwrap();
            assert!(s.completed > 0, "{name} completed nothing");
        }
    }

    #[test]
    fn accounting_identity_holds() {
        // generated == completed + unserved (via sla totals)
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let costs = toy_costs(&m);
        let s = simulate(&base_cfg(), &m, &costs).unwrap();
        assert!(s.sla_met <= s.completed);
        assert!(s.completed <= s.generated);
    }
}
