//! Zipf(s) popularity sampler over model ranks.
//!
//! Rank i (0-based) gets weight `(i+1)^-s`, normalized; `s = 0` is
//! uniform, larger `s` concentrates mass on rank 0.  Sampling is one
//! `next_f64` + a binary search over the precomputed CDF, so the draw
//! count per request is fixed and seed-reproducible.

use crate::traffic::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct Zipf {
    weights: Vec<f64>,
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler over `n` ranks with skew `s >= 0`.
    pub fn new(n: usize, skew: f64) -> Zipf {
        assert!(n > 0, "Zipf needs at least one rank");
        assert!(skew >= 0.0 && skew.is_finite(), "Zipf skew must be >= 0");
        let raw: Vec<f64> = (0..n)
            .map(|i| ((i + 1) as f64).powf(-skew))
            .collect();
        let total: f64 = raw.iter().sum();
        let weights: Vec<f64> = raw.iter().map(|w| w / total).collect();
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for w in &weights {
            acc += w;
            cdf.push(acc);
        }
        // guard against float drift: the last bucket must cover 1.0
        if let Some(last) = cdf.last_mut() {
            *last = 1.0;
        }
        Zipf { weights, cdf }
    }

    /// Normalized rank weights (sum to 1).
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Draw a rank in `[0, n)`.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        // first bucket whose cumulative weight exceeds u
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(self.cdf.len() - 1),
            Err(i) => i,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_zero_is_uniform() {
        let z = Zipf::new(5, 0.0);
        for &w in z.weights() {
            assert!((w - 0.2).abs() < 1e-12);
        }
    }

    #[test]
    fn weights_normalized_and_monotone() {
        let z = Zipf::new(8, 1.2);
        let sum: f64 = z.weights().iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        for w in z.weights().windows(2) {
            assert!(w[0] > w[1], "weights must strictly decrease");
        }
    }

    #[test]
    fn sampling_tracks_weights() {
        let z = Zipf::new(4, 1.0);
        let mut rng = Pcg64::new(17);
        let mut counts = [0u64; 4];
        let n = 40_000;
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let got = c as f64 / n as f64;
            assert!((got - z.weights()[i]).abs() < 0.02,
                    "rank {i}: {got} vs {}", z.weights()[i]);
        }
    }

    #[test]
    fn single_rank_always_zero() {
        let z = Zipf::new(1, 2.0);
        let mut rng = Pcg64::new(1);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 0);
        }
    }
}
