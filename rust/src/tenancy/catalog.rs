//! Synthetic model catalog: N families cloned from the manifest's
//! real ones with cycled size multipliers, so `--catalog 64` stresses
//! the swap path with a realistic spread of model sizes without any
//! artifacts on disk.
//!
//! Catalog families are DES-only: they have no weight blobs or
//! compiled executables, so `serve` refuses them.  The lab runner
//! builds an expanded manifest plus a `CostModel::synthetic` table
//! per cell, which prices each `cat-*` family from its (scaled)
//! weight bytes exactly like the base families.

use crate::runtime::manifest::{FamilySpec, Manifest};

/// Size multipliers cycled across the catalog, small/base/large.
const SIZE_MULT: [f64; 3] = [0.6, 1.0, 1.6];

/// Names of the `n` synthetic catalog models, in Zipf rank order.
pub fn catalog_models(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("cat-{i:02}")).collect()
}

/// Clone one base family into a scaled catalog entry.
fn scaled_family(base: &FamilySpec, name: String, mult: f64) -> FamilySpec {
    let mut f = base.clone();
    f.name = name;
    f.hf_name = format!("synthetic/{}", f.name);
    f.paper_gb = base.paper_gb * mult;
    f.param_count = (base.param_count as f64 * mult) as u64;
    f.kv_bytes_per_seq = ((base.kv_bytes_per_seq as f64 * mult) as u64).max(1);
    f.weights.total_bytes =
        ((base.weights.total_bytes as f64 * mult) as usize).max(1);
    f.weights.file = String::new();
    f.weights.sha256 = String::new();
    // artifacts stay cloned from the base: batch-size selection needs a
    // non-empty table, and the DES prices batches from the cost model,
    // not the artifact files
    f
}

/// Expanded manifest: the base families plus `n` catalog entries
/// (`cat-00` .. ), each cloned round-robin from a base family with a
/// cycled size multiplier.  Deterministic — no RNG — so every run and
/// both lab threads build the identical catalog.
pub fn expand_manifest(base: &Manifest, n: usize) -> Manifest {
    let mut m = base.clone();
    for (i, name) in catalog_models(n).into_iter().enumerate() {
        let src = &base.families[i % base.families.len()];
        let mult = SIZE_MULT[i % SIZE_MULT.len()];
        m.families.push(scaled_family(src, name, mult));
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn base() -> Manifest {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).expect("run `make artifacts` first")
    }

    #[test]
    fn names_are_rank_ordered() {
        assert_eq!(catalog_models(3),
                   vec!["cat-00", "cat-01", "cat-02"]);
        assert!(catalog_models(0).is_empty());
    }

    #[test]
    fn expansion_keeps_base_families_and_adds_n() {
        let b = base();
        let m = expand_manifest(&b, 6);
        assert_eq!(m.families.len(), b.families.len() + 6);
        for name in catalog_models(6) {
            let f = m.family(&name).unwrap();
            assert!(!f.artifacts.is_empty(),
                    "catalog family must keep artifact batch sizes");
            assert!(f.weight_bytes() > 0);
            // batch-size selection must not panic on an empty table
            let _ = f.batch_size_at_least(1);
        }
    }

    #[test]
    fn sizes_cycle() {
        let b = base();
        let m = expand_manifest(&b, 6);
        let w0 = m.family("cat-00").unwrap().weight_bytes() as f64;
        let base0 = b.families[0].weight_bytes() as f64;
        assert!((w0 / base0 - 0.6).abs() < 1e-6);
        let w1 = m.family("cat-01").unwrap().weight_bytes() as f64;
        let base1 = b.families[1].weight_bytes() as f64;
        assert!((w1 / base1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn expansion_is_deterministic() {
        let b = base();
        let a = expand_manifest(&b, 4);
        let c = expand_manifest(&b, 4);
        for (x, y) in a.families.iter().zip(&c.families) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.weights.total_bytes, y.weights.total_bytes);
        }
    }
}
