//! Admission control in front of the model queues.
//!
//! The gate sees every request at ingest time, *before* it is queued,
//! and may shed it instead.  A shed request counts against SLA
//! attainment immediately (it was generated and refused), but never
//! occupies queue or device time — which is the whole point: under
//! the CC swap tax, queueing infeasible work only makes every other
//! tenant miss too.
//!
//! Policies live behind a name table (`ADMISSIONS`), mirroring the
//! scheduler's `STRATEGIES`, so the CLI, the lab axes and `validate()`
//! share one source of truth.  Every decision is a pure function of
//! virtual-time-domain inputs (queue lengths, cost-table estimates,
//! class deadlines), so DES and real-virtual backends shed exactly
//! the same requests — parity-pinned in `tests/engine_parity.rs`.

use super::{class_deadline_s, CLASS_WEIGHT, N_CLASSES};

/// Everything a policy may look at for one decision.
#[derive(Debug, Clone)]
pub struct AdmitCtx {
    /// Virtual now (seconds since run start) at ingest.
    pub now_s: f64,
    /// Arrival time of the request.
    pub arrival_s: f64,
    /// Tenant class (0 = gold); 0 when SLA classes are off.
    pub class: u8,
    /// Base SLA window (seconds); per-class deadlines derive from it.
    pub sla_s: f64,
    /// Whether per-class deadlines apply (else everyone gets `sla_s`).
    pub classes_on: bool,
    /// Queued requests for this request's model.
    pub queue_len: usize,
    /// Queued requests across all models.
    pub total_queued: usize,
    /// Queued requests per class.
    pub class_queued: [u64; N_CLASSES],
    /// System queue cap: `ceil(mean_rps * sla_s)` — one SLA window of
    /// offered load.
    pub queue_cap: usize,
    /// Cheapest load estimate for this model over free devices (0 if
    /// already resident somewhere).
    pub est_load_s: f64,
    /// Cost-table execution estimate for one batch of this model.
    pub est_exec_s: f64,
    /// Max batch rows the runtime will form.
    pub obs: usize,
}

impl AdmitCtx {
    /// Seconds left before this request's deadline.
    pub fn remaining_s(&self) -> f64 {
        let window = if self.classes_on {
            class_deadline_s(self.class, self.sla_s)
        } else {
            self.sla_s
        };
        self.arrival_s + window - self.now_s
    }
}

/// One admission policy; `admit` returns false to shed.
pub trait AdmissionPolicy: Send {
    fn name(&self) -> &'static str;
    fn admit(&mut self, ctx: &AdmitCtx) -> bool;
}

/// `none`: the pre-tenancy behavior — everything is queued.
struct NoGate;

impl AdmissionPolicy for NoGate {
    fn name(&self) -> &'static str {
        "none"
    }
    fn admit(&mut self, _ctx: &AdmitCtx) -> bool {
        true
    }
}

/// `queue-cap`: shed once the total backlog exceeds one SLA window of
/// offered load, regardless of class.
struct QueueCap;

impl AdmissionPolicy for QueueCap {
    fn name(&self) -> &'static str {
        "queue-cap"
    }
    fn admit(&mut self, ctx: &AdmitCtx) -> bool {
        ctx.total_queued < ctx.queue_cap
    }
}

/// `deadline-infeasible`: shed a request whose deadline cannot be met
/// even optimistically — the cheapest possible load plus the batches
/// already ahead of it in its own queue exceed the remaining window.
struct DeadlineInfeasible;

impl AdmissionPolicy for DeadlineInfeasible {
    fn name(&self) -> &'static str {
        "deadline-infeasible"
    }
    fn admit(&mut self, ctx: &AdmitCtx) -> bool {
        let obs = ctx.obs.max(1);
        let batches_ahead = (ctx.queue_len / obs + 1) as f64;
        let eta_s = ctx.est_load_s + batches_ahead * ctx.est_exec_s;
        eta_s <= ctx.remaining_s()
    }
}

/// `class-weighted`: each class owns a share of the queue cap
/// proportional to its weight (gold 3 : silver 2 : free 1); a class
/// over its share is shed.  Free tenants therefore shed first as the
/// backlog grows — shed priority without touching the scheduler.
struct ClassWeighted;

impl AdmissionPolicy for ClassWeighted {
    fn name(&self) -> &'static str {
        "class-weighted"
    }
    fn admit(&mut self, ctx: &AdmitCtx) -> bool {
        let total_w: u64 = CLASS_WEIGHT.iter().sum();
        let w = CLASS_WEIGHT[ctx.class as usize % N_CLASSES];
        // ceil(cap * w / total_w), never below 1
        let share = ((ctx.queue_cap as u64 * w + total_w - 1) / total_w).max(1);
        ctx.class_queued[ctx.class as usize % N_CLASSES] < share
    }
}

/// Name-table entry, mirroring `STRATEGIES`/`PLACEMENTS`.
pub struct AdmissionEntry {
    pub name: &'static str,
    pub blurb: &'static str,
    pub make: fn() -> Box<dyn AdmissionPolicy>,
}

pub const ADMISSIONS: &[AdmissionEntry] = &[
    AdmissionEntry {
        name: "none",
        blurb: "queue everything (pre-tenancy behavior)",
        make: || Box::new(NoGate),
    },
    AdmissionEntry {
        name: "queue-cap",
        blurb: "shed when total backlog exceeds one SLA window of load",
        make: || Box::new(QueueCap),
    },
    AdmissionEntry {
        name: "deadline-infeasible",
        blurb: "shed requests whose deadline is already unreachable",
        make: || Box::new(DeadlineInfeasible),
    },
    AdmissionEntry {
        name: "class-weighted",
        blurb: "per-class queue shares (gold 3 : silver 2 : free 1)",
        make: || Box::new(ClassWeighted),
    },
];

/// Instantiate a policy by name.
pub fn admission_by_name(name: &str)
                         -> anyhow::Result<Box<dyn AdmissionPolicy>> {
    ADMISSIONS.iter().find(|e| e.name == name).map(|e| (e.make)())
        .ok_or_else(|| anyhow::anyhow!(
            "unknown admission policy {name:?} (have {:?})",
            admission_names()))
}

pub fn admission_names() -> Vec<&'static str> {
    ADMISSIONS.iter().map(|e| e.name).collect()
}

/// System queue cap shared by the capped policies.
pub fn queue_cap(mean_rps: f64, sla_s: f64) -> usize {
    (mean_rps * sla_s).ceil().max(1.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> AdmitCtx {
        AdmitCtx {
            now_s: 10.0,
            arrival_s: 10.0,
            class: 2,
            sla_s: 6.0,
            classes_on: true,
            queue_len: 0,
            total_queued: 0,
            class_queued: [0; N_CLASSES],
            queue_cap: 24,
            est_load_s: 0.0,
            est_exec_s: 0.2,
            obs: 8,
        }
    }

    #[test]
    fn table_resolves_every_name() {
        for e in ADMISSIONS {
            let p = admission_by_name(e.name).unwrap();
            assert_eq!(p.name(), e.name);
        }
        assert!(admission_by_name("fifo").is_err());
        assert_eq!(admission_names().len(), 4);
    }

    #[test]
    fn none_admits_everything() {
        let mut p = admission_by_name("none").unwrap();
        let mut c = ctx();
        c.total_queued = 10_000;
        assert!(p.admit(&c));
    }

    #[test]
    fn queue_cap_sheds_at_cap() {
        let mut p = admission_by_name("queue-cap").unwrap();
        let mut c = ctx();
        c.total_queued = 23;
        assert!(p.admit(&c));
        c.total_queued = 24;
        assert!(!p.admit(&c));
    }

    #[test]
    fn deadline_infeasible_sheds_hopeless_requests() {
        let mut p = admission_by_name("deadline-infeasible").unwrap();
        let mut c = ctx();
        // empty system, resident model: trivially feasible
        assert!(p.admit(&c));
        // a cold load longer than the free-class window: shed
        c.est_load_s = 100.0;
        assert!(!p.admit(&c));
        // gold deadline (3 s) vs a 2.8 s ETA: feasible...
        c.est_load_s = 2.6;
        c.class = 0;
        assert!(p.admit(&c));
        // ...until the queue ahead pushes the ETA past it
        c.queue_len = 16;
        assert!(!p.admit(&c));
    }

    #[test]
    fn class_weighted_gives_gold_the_biggest_share() {
        let mut p = admission_by_name("class-weighted").unwrap();
        // cap 24, weights 3:2:1 -> shares gold 12, silver 8, free 4
        let mut c = ctx();
        c.class = 2;
        c.class_queued = [0, 0, 4];
        assert!(!p.admit(&c), "free over its share must shed");
        c.class = 0;
        c.class_queued = [11, 0, 4];
        assert!(p.admit(&c), "gold under its share is admitted");
        c.class_queued = [12, 0, 4];
        assert!(!p.admit(&c));
    }

    #[test]
    fn cap_is_one_sla_window_of_load() {
        assert_eq!(queue_cap(4.0, 6.0), 24);
        assert_eq!(queue_cap(0.1, 1.0), 1);
    }
}
