//! Multi-tenant serving: a synthetic model catalog, Zipf-skewed
//! popularity, per-tenant SLA classes, and an admission-control gate
//! (ROADMAP item 1 — "millions of users" means the coordinator must
//! sometimes *refuse* work instead of queueing it to death).
//!
//! Everything here is strictly additive: with `--catalog 0`,
//! `--zipf-skew off`, `--admission none` and `--sla-classes off` the
//! engine draws no extra RNG values and the summary JSON carries no
//! tenancy key, so runs reduce byte-identically to pre-tenancy builds
//! (pinned by the golden-summary suite).

pub mod admission;
pub mod catalog;
pub mod zipf;

use crate::traffic::rng::Pcg64;

/// SLA class count: gold / silver / free.
pub const N_CLASSES: usize = 3;

/// Class names in priority order (class 0 = most protected).
pub const CLASS_NAMES: [&str; N_CLASSES] = ["gold", "silver", "free"];

/// Per-class deadline as a fraction of the base `--sla` limit: gold
/// gets half the window, free gets 1.5x.
pub const CLASS_DEADLINE_FRAC: [f64; N_CLASSES] = [0.5, 1.0, 1.5];

/// Admission weights for the `class-weighted` policy (share of the
/// queue cap each class may occupy).
pub const CLASS_WEIGHT: [u64; N_CLASSES] = [3, 2, 1];

/// Population mix: 20% gold, 30% silver, 50% free.
pub const CLASS_MIX: [f64; N_CLASSES] = [0.2, 0.3, 0.5];

/// Completion deadline (seconds after arrival) for a class.
pub fn class_deadline_s(class: u8, sla_s: f64) -> f64 {
    CLASS_DEADLINE_FRAC[class as usize % N_CLASSES] * sla_s
}

/// Draw a tenant class from the population mix (one `next_f64` per
/// request, always from a dedicated forked stream so the base
/// schedule is untouched).
pub fn assign_class(rng: &mut Pcg64) -> u8 {
    let u = rng.next_f64();
    if u < CLASS_MIX[0] {
        0
    } else if u < CLASS_MIX[0] + CLASS_MIX[1] {
        1
    } else {
        2
    }
}

/// Per-class counters accumulated by the engine while a tenancy
/// feature (admission gate or SLA classes) is active.  With classes
/// off every request lands in class 0.
#[derive(Debug, Clone, Default)]
pub struct TenancyStats {
    pub generated: [u64; N_CLASSES],
    pub shed: [u64; N_CLASSES],
    pub expired: [u64; N_CLASSES],
    pub completed: [u64; N_CLASSES],
    pub met: [u64; N_CLASSES],
}

impl TenancyStats {
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }
}

/// Jain fairness index over per-class SLA attainments:
/// `(Σx)² / (n·Σx²)` — 1.0 when every class is served equally well,
/// approaching `1/n` when one class starves the rest.  Classes with
/// no traffic are skipped; an empty or all-zero sample is perfectly
/// fair by convention.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    let xs: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        1.0
    } else {
        sum * sum / (xs.len() as f64 * sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_mix_draws_every_class() {
        let mut rng = Pcg64::new(3);
        let mut counts = [0u64; N_CLASSES];
        let n = 30_000;
        for _ in 0..n {
            counts[assign_class(&mut rng) as usize] += 1;
        }
        for (c, &want) in CLASS_MIX.iter().enumerate() {
            let got = counts[c] as f64 / n as f64;
            assert!((got - want).abs() < 0.02,
                    "class {c}: {got} vs mix {want}");
        }
    }

    #[test]
    fn deadlines_ordered_by_priority() {
        assert!(class_deadline_s(0, 6.0) < class_deadline_s(1, 6.0));
        assert!(class_deadline_s(1, 6.0) < class_deadline_s(2, 6.0));
        assert_eq!(class_deadline_s(1, 6.0), 6.0);
    }

    #[test]
    fn jain_bounds() {
        assert_eq!(jain_fairness(&[]), 1.0);
        assert_eq!(jain_fairness(&[0.0, 0.0]), 1.0);
        assert!((jain_fairness(&[0.7, 0.7, 0.7]) - 1.0).abs() < 1e-12);
        // one class starves: index tends to 1/n
        let skewed = jain_fairness(&[1.0, 0.0, 0.0]);
        assert!((skewed - 1.0 / 3.0).abs() < 1e-12);
        let mid = jain_fairness(&[0.9, 0.6, 0.3]);
        assert!(mid > 1.0 / 3.0 && mid < 1.0, "{mid}");
    }

    #[test]
    fn stats_total() {
        let mut t = TenancyStats::default();
        t.shed = [1, 2, 3];
        assert_eq!(t.shed_total(), 6);
    }
}
