//! Process/system sampling — the py-hardware-monitor stand-in (§V).
//!
//! Reads `/proc/self/*` for CPU time, RSS and context switches; device
//! metrics (occupancy, memory, fragmentation, DMA counters) come from
//! `SimGpu` and are merged into the monitor CSV by the recorder.

/// One sample of process-level counters.
#[derive(Debug, Clone, Default)]
pub struct ProcSample {
    /// Monotonic timestamp (seconds since an arbitrary epoch).
    pub at_s: f64,
    /// Cumulative user CPU seconds of this process.
    pub cpu_user_s: f64,
    /// Cumulative system CPU seconds.
    pub cpu_sys_s: f64,
    /// Resident set size, bytes.
    pub rss_bytes: u64,
    /// Voluntary context switches (cumulative).
    pub vol_ctxt: u64,
    /// Involuntary context switches (cumulative).
    pub invol_ctxt: u64,
}

fn clock_ticks_per_sec() -> f64 {
    // SAFETY: sysconf is always safe to call.
    let t = unsafe { libc::sysconf(libc::_SC_CLK_TCK) };
    if t > 0 { t as f64 } else { 100.0 }
}

fn page_size() -> u64 {
    let p = unsafe { libc::sysconf(libc::_SC_PAGESIZE) };
    if p > 0 { p as u64 } else { 4096 }
}

/// Sample the current process. Returns a zeroed sample on any parse
/// failure (monitoring must never kill an experiment).
pub fn sample_proc(at_s: f64) -> ProcSample {
    let mut s = ProcSample { at_s, ..Default::default() };

    if let Ok(stat) = std::fs::read_to_string("/proc/self/stat") {
        // fields after the parenthesized comm; utime/stime are fields 14
        // and 15 (1-based), i.e. indices 11 and 12 after the comm.
        if let Some(idx) = stat.rfind(')') {
            let f: Vec<&str> = stat[idx + 1..].split_whitespace().collect();
            let ticks = clock_ticks_per_sec();
            if f.len() > 12 {
                s.cpu_user_s = f[11].parse::<f64>().unwrap_or(0.0) / ticks;
                s.cpu_sys_s = f[12].parse::<f64>().unwrap_or(0.0) / ticks;
            }
        }
    }
    if let Ok(statm) = std::fs::read_to_string("/proc/self/statm") {
        if let Some(rss_pages) = statm.split_whitespace().nth(1) {
            s.rss_bytes = rss_pages.parse::<u64>().unwrap_or(0) * page_size();
        }
    }
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(v) = line.strip_prefix("voluntary_ctxt_switches:") {
                s.vol_ctxt = v.trim().parse().unwrap_or(0);
            }
            if let Some(v) = line.strip_prefix("nonvoluntary_ctxt_switches:")
            {
                s.invol_ctxt = v.trim().parse().unwrap_or(0);
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_works_on_linux() {
        let s = sample_proc(1.0);
        assert_eq!(s.at_s, 1.0);
        assert!(s.rss_bytes > 0, "rss should be nonzero");
        // burn some CPU, expect the counter to move
        let before = s.cpu_user_s + s.cpu_sys_s;
        let mut acc = 0u64;
        for i in 0..20_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let after = sample_proc(2.0);
        assert!(after.cpu_user_s + after.cpu_sys_s >= before);
    }
}
