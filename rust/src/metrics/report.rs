//! Paper-style report rendering from run summaries.
//!
//! Turns a set of `RunSummary` cells into the tables behind Fig 5/6/7
//! and the abstract's headline ratios, as markdown.

use crate::engine::RunSummary;

/// Render a markdown table of the given summaries, one row per cell.
pub fn cells_table(cells: &[RunSummary]) -> String {
    let mut out = String::from(
        "| mode | pattern | strategy | SLA (s) | gen | done | attain % | \
         lat mean (s) | lat p99 (s) | thr (rps) | proc rate (rps) | \
         GPU util % | swaps |\n|---|---|---|---|---|---|---|---|---|---|---|---|---|\n");
    for c in cells {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {:.1} | {:.2} | {:.2} | \
             {:.2} | {:.2} | {:.1} | {} |\n",
            c.mode, c.pattern, c.strategy, c.sla_s, c.generated,
            c.completed, c.sla_attainment * 100.0, c.latency_mean_s,
            c.latency_p99_s, c.throughput_rps, c.processing_rate_rps,
            c.gpu_util * 100.0, c.swap_count));
    }
    out
}

/// Render the per-device breakdown of fleet cells (cells with a
/// single device contribute nothing — their totals are already the
/// cells-table row).  Fixes the gap where `RunSummary::per_device`
/// was serialized but never rendered.
pub fn per_device_table(cells: &[RunSummary]) -> String {
    let mut out = String::from(
        "| cell | dev | mode | batches | done | exec (s) | util % | \
         swaps | load (s) | crypto exp (s) | prefetch | promoted |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|\n");
    for c in cells.iter().filter(|c| c.per_device.len() > 1) {
        for d in &c.per_device {
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {:.2} | {:.1} | {} | \
                 {:.2} | {:.3} | {} | {} |\n",
                c.label, d.device, d.mode, d.batches, d.completed,
                d.exec_s, d.util * 100.0, d.swap_count, d.load_s,
                d.crypto_exposed_s, d.prefetches, d.promotions));
        }
    }
    out
}

/// One cell's data-path gate, shared by `has_data_path` and the table
/// row filter so the section header and its rows cannot disagree.
/// Keyed on bytes (not just crypto) so a `--cc-crypto-frac 0` run
/// still reports its payload traffic.
fn cell_has_data(c: &RunSummary) -> bool {
    c.data_bytes > 0 || c.total_data_crypto_s > 0.0
}

/// True when any cell shipped CC data-path batch I/O — gates the
/// batch-I/O table the same way fleet cells gate `per_device_table`.
pub fn has_data_path(cells: &[RunSummary]) -> bool {
    cells.iter().any(cell_has_data)
}

/// Fig-3-style batch-I/O table of the CC-priced inference data path:
/// per cell, the payload volume, the wire amplification the bounce
/// framing adds, total vs exposed payload crypto, and the crypto cost
/// per completed request.  Cells that priced no CC batch I/O (flag
/// off, or No-CC) contribute no rows.
pub fn data_path_table(cells: &[RunSummary]) -> String {
    let mut out = String::from(
        "| cell | mode | data (MB) | wire amp | data crypto (s) | \
         exposed (s) | crypto/req (ms) | of runtime % |\n\
         |---|---|---|---|---|---|---|---|\n");
    for c in cells.iter().filter(|c| cell_has_data(c)) {
        let amp = if c.data_bytes > 0 {
            c.data_wire_bytes as f64 / c.data_bytes as f64
        } else {
            1.0
        };
        let per_req_ms = if c.completed > 0 {
            c.total_data_crypto_s * 1e3 / c.completed as f64
        } else {
            0.0
        };
        let share = if c.runtime_s > 0.0 {
            c.total_data_crypto_s
                / (c.runtime_s * c.devices.max(1) as f64) * 100.0
        } else {
            0.0
        };
        out.push_str(&format!(
            "| {} | {} | {:.3} | {:.3}x | {:.3} | {:.3} | {:.3} | \
             {:.2} |\n",
            c.label, c.mode, c.data_bytes as f64 / 1e6, amp,
            c.total_data_crypto_s, c.total_data_crypto_exposed_s,
            per_req_ms, share));
    }
    out
}

/// True when any cell ran with tenancy features (admission gate or
/// SLA classes) — gates the multi-tenant table the same way
/// `has_data_path` gates the batch-I/O table.
pub fn has_tenancy(cells: &[RunSummary]) -> bool {
    cells.iter().any(|c| c.tenancy.is_some())
}

/// Multi-tenant table: per cell, the admission policy, shed volume,
/// goodput (SLA-met completions per second), Jain fairness across
/// class attainments, per-class shed rates, and the most-reloaded
/// catalog model (swap churn).  Cells without a tenancy block (flags
/// off) contribute no rows — mirroring the tenancy-off byte-identity
/// contract.
pub fn tenancy_table(cells: &[RunSummary]) -> String {
    let mut out = String::from(
        "| cell | admission | shed | goodput (rps) | fairness | \
         gold shed % | silver shed % | free shed % | top churn |\n\
         |---|---|---|---|---|---|---|---|---|\n");
    for c in cells {
        let Some(t) = &c.tenancy else { continue };
        let class_shed = |name: &str| -> String {
            match t.classes.iter().find(|k| k.name == name) {
                Some(k) if k.generated > 0 => format!(
                    "{:.1}", k.shed as f64 / k.generated as f64 * 100.0),
                Some(_) => "0.0".to_string(),
                None => "-".to_string(),
            }
        };
        let churn = t.churn_by_model.iter()
            .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
            .map(|(m, n)| format!("{m} x{n}"))
            .unwrap_or_else(|| "-".to_string());
        out.push_str(&format!(
            "| {} | {} | {} | {:.2} | {:.3} | {} | {} | {} | {} |\n",
            c.label, t.admission, t.shed_total, t.goodput_rps,
            t.fairness, class_shed("gold"), class_shed("silver"),
            class_shed("free"), churn));
    }
    out
}

/// Profile name parsed from a cell label's `_prof-` fragment
/// (profile names never contain `_`, so the next `_` or the end of
/// the label terminates it).
fn profile_of(c: &RunSummary) -> Option<&str> {
    let i = c.label.find("_prof-")?;
    let rest = &c.label[i + "_prof-".len()..];
    Some(rest.split('_').next().unwrap_or(rest))
}

/// True when any cell ran under a named device profile — gates the
/// hardware-generation table the same way `has_data_path` gates the
/// batch-I/O table.  Keyed on the label fragment, not a summary
/// field, so profile-free runs keep their summaries byte-identical.
pub fn has_profiles(cells: &[RunSummary]) -> bool {
    cells.iter().any(|c| profile_of(c).is_some())
}

/// "CC tax by hardware generation": per profile, the CC-vs-No-CC
/// latency and attainment gap, and how the CC swap tax splits between
/// chunk crypto (`total_crypto_s`) and the per-swap bridge residual
/// (`total_bridge_s`).  A Hopper profile concentrates the tax in
/// crypto, a coherent one in the bridge.  Cells without a `_prof-`
/// fragment contribute no rows.
pub fn hw_gen_table(cells: &[RunSummary]) -> String {
    let mut order: Vec<String> = Vec::new();
    for c in cells {
        if let Some(p) = profile_of(c) {
            if !order.iter().any(|o| o == p) {
                order.push(p.to_string());
            }
        }
    }
    let mut out = String::from(
        "| profile | cells | lat no-cc (s) | lat cc (s) | gap % | \
         attain gap (pts) | swap crypto (s) | bridge (s) | \
         crypto share % | bridge share % |\n\
         |---|---|---|---|---|---|---|---|---|---|\n");
    for p in &order {
        let in_prof =
            |c: &RunSummary| profile_of(c) == Some(p.as_str());
        let cc = |c: &RunSummary| in_prof(c) && c.mode == "cc";
        let nocc = |c: &RunSummary| in_prof(c) && c.mode == "no-cc";
        let n = cells.iter().filter(|c| in_prof(c)).count();
        let lat_cc = mean_where(cells, cc, |c| c.latency_mean_s);
        let lat_nocc = mean_where(cells, nocc, |c| c.latency_mean_s);
        let gap = if lat_nocc > 0.0 {
            (lat_cc - lat_nocc) / lat_nocc * 100.0
        } else {
            0.0
        };
        let att_gap = (mean_where(cells, nocc, |c| c.sla_attainment)
                       - mean_where(cells, cc, |c| c.sla_attainment))
            * 100.0;
        let crypto = mean_where(cells, cc, |c| c.total_crypto_s);
        let bridge = mean_where(cells, cc, |c| c.total_bridge_s);
        let tax = crypto + bridge;
        let (cs, bs) = if tax > 0.0 {
            (crypto / tax * 100.0, bridge / tax * 100.0)
        } else {
            (0.0, 0.0)
        };
        out.push_str(&format!(
            "| {} | {} | {:.2} | {:.2} | {:+.1} | {:+.1} | {:.2} | \
             {:.2} | {:.1} | {:.1} |\n",
            p, n, lat_nocc, lat_cc, gap, att_gap, crypto, bridge,
            cs, bs));
    }
    out
}

/// True when any cell carries the trace layer's `phase_totals`
/// aggregate — gates the waterfall table the same way `has_data_path`
/// gates the batch-I/O table.  Untraced runs attach no block, so
/// trace-off reports cannot change by a byte.
pub fn has_waterfall(cells: &[RunSummary]) -> bool {
    cells.iter().any(|c| c.phase_totals.is_some())
}

/// "Where the seconds go": per traced cell, the mean seconds each
/// completed request spent in every lifecycle phase (queue wait, swap
/// unload/load with the load's bridge and exposed-crypto attribution,
/// exec, data-path I/O) plus the per-phase p95s — the per-request
/// waterfall identity aggregated (`obs::Waterfall`).  A second block
/// gives the CC-minus-No-CC per-phase delta for each hardware profile
/// (and `-` for profile-free cells), naming the phase that pays the
/// largest share of the CC tax.  Cells without a `phase_totals` block
/// (trace off) contribute no rows.
pub fn waterfall_table(cells: &[RunSummary]) -> String {
    use crate::obs::PhaseTotals;
    let mut out = String::from(
        "| cell | mode | reqs | queue (s) | q p95 | unload (s) | \
         load (s) | bridge (s) | crypto exp (s) | load p95 | \
         exec (s) | exec p95 | io (s) | lat (s) |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n");
    for c in cells {
        let Some(p) = &c.phase_totals else { continue };
        out.push_str(&format!(
            "| {} | {} | {} | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} | \
             {:.3} | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} |\n",
            c.label, c.mode, p.requests,
            p.mean(p.queue_wait_s), p.queue_wait_p95_s,
            p.mean(p.swap_unload_s), p.mean(p.swap_load_s),
            p.mean(p.swap_bridge_s), p.mean(p.swap_crypto_exposed_s),
            p.swap_load_p95_s,
            p.mean(p.exec_s), p.exec_p95_s,
            p.mean(p.io_s), p.mean(p.latency_s)));
    }
    // CC-minus-No-CC per-phase deltas, one row per profile group with
    // traced cells on both sides of the mode axis
    let group_of = |c: &RunSummary| -> String {
        profile_of(c).unwrap_or("-").to_string()
    };
    let mut order: Vec<String> = Vec::new();
    for c in cells.iter().filter(|c| c.phase_totals.is_some()) {
        let g = group_of(c);
        if !order.contains(&g) {
            order.push(g);
        }
    }
    let pmean = |pred: &dyn Fn(&RunSummary) -> bool,
                 metric: &dyn Fn(&PhaseTotals) -> f64| -> f64 {
        let vals: Vec<f64> = cells.iter()
            .filter(|c| pred(c))
            .filter_map(|c| c.phase_totals.as_ref().map(metric))
            .collect();
        crate::util::mean(&vals)
    };
    let mut deltas = String::new();
    for g in &order {
        let cc = |c: &RunSummary| group_of(c) == *g && c.mode == "cc";
        let nocc =
            |c: &RunSummary| group_of(c) == *g && c.mode == "no-cc";
        let both = cells.iter().any(|c| c.phase_totals.is_some() && cc(c))
            && cells.iter().any(|c| c.phase_totals.is_some() && nocc(c));
        if !both {
            continue;
        }
        let d = |metric: &dyn Fn(&PhaseTotals) -> f64| -> f64 {
            pmean(&cc, metric) - pmean(&nocc, metric)
        };
        let dq = d(&|p| p.mean(p.queue_wait_s));
        let dswap =
            d(&|p| p.mean(p.swap_unload_s) + p.mean(p.swap_load_s));
        let dexec = d(&|p| p.mean(p.exec_s));
        let dio = d(&|p| p.mean(p.io_s));
        let dlat = d(&|p| p.mean(p.latency_s));
        let phases =
            [("queue", dq), ("swap", dswap), ("exec", dexec),
             ("io", dio)];
        let driver = phases.iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .map(|(n, _)| *n).unwrap_or("-");
        deltas.push_str(&format!(
            "| {} | {:+.3} | {:+.3} | {:+.3} | {:+.3} | {:+.3} | \
             {} |\n",
            g, dq, dswap, dexec, dio, dlat, driver));
    }
    if !deltas.is_empty() {
        out.push_str(
            "\nCC tax by phase (CC minus No-CC, mean s/request):\n\n\
             | profile | d queue | d swap | d exec | d io | d lat | \
             tax driver |\n|---|---|---|---|---|---|---|\n");
        out.push_str(&deltas);
    }
    out
}

/// True when any cell ran pipeline-parallel (`pp_stages` > 1) — gates
/// the stage-scaling table the same way `has_data_path` gates the
/// batch-I/O table.  Stage-free grids keep their reports
/// byte-identical.
pub fn has_pipeline(cells: &[RunSummary]) -> bool {
    cells.iter().any(|c| c.pp_stages > 1)
}

/// "CC tax by stage count": per (profile, stage-count) group, the
/// CC-vs-No-CC latency gap plus the CC side's pipeline signature —
/// TTFT, per-token throughput, bubble time from stage imbalance, and
/// the sealed inter-stage activation traffic (wire volume, total vs
/// exposed crypto).  Profile-major with stages ascending, so each
/// profile's column reads top-to-bottom as "how the CC tax grows with
/// stage count" and comparing blocks answers "which hardware
/// generation flattens it".  Stage-1 cells anchor each profile's
/// baseline row.
pub fn pipeline_table(cells: &[RunSummary]) -> String {
    let mut order: Vec<(String, usize)> = Vec::new();
    for c in cells {
        let key = (profile_of(c).unwrap_or("-").to_string(),
                   c.pp_stages.max(1));
        if !order.contains(&key) {
            order.push(key);
        }
    }
    order.sort();
    let mut out = String::from(
        "| profile | stages | lat no-cc (s) | lat cc (s) | CC tax % | \
         ttft cc (s) | tok (tps) | bubble (s) | act wire (MB) | \
         act crypto (s) | exposed (s) |\n\
         |---|---|---|---|---|---|---|---|---|---|---|\n");
    for (p, st) in &order {
        let in_group = |c: &RunSummary| {
            profile_of(c).unwrap_or("-") == p.as_str()
                && c.pp_stages.max(1) == *st
        };
        let cc = |c: &RunSummary| in_group(c) && c.mode == "cc";
        let nocc = |c: &RunSummary| in_group(c) && c.mode == "no-cc";
        let lat_cc = mean_where(cells, cc, |c| c.latency_mean_s);
        let lat_nocc = mean_where(cells, nocc, |c| c.latency_mean_s);
        let tax = if lat_nocc > 0.0 {
            (lat_cc - lat_nocc) / lat_nocc * 100.0
        } else {
            0.0
        };
        out.push_str(&format!(
            "| {} | {} | {:.2} | {:.2} | {:+.1} | {:.2} | {:.1} | \
             {:.2} | {:.3} | {:.3} | {:.3} |\n",
            p, st, lat_nocc, lat_cc, tax,
            mean_where(cells, cc, |c| c.ttft_mean_s),
            mean_where(cells, cc, |c| c.token_throughput_tps),
            mean_where(cells, cc, |c| c.total_bubble_s),
            mean_where(cells, cc,
                       |c| c.activation_wire_bytes as f64 / 1e6),
            mean_where(cells, cc, |c| c.total_activation_crypto_s),
            mean_where(cells, cc,
                       |c| c.total_activation_crypto_exposed_s)));
    }
    out
}

/// Mean of the headline metrics grouped by one axis of a grid
/// (`mode` | `pattern` | `strategy` | `sla`), one row per distinct
/// value in first-appearance order.
pub fn grouped_table(cells: &[RunSummary], group: &str)
                     -> anyhow::Result<String> {
    let key: fn(&RunSummary) -> String = match group {
        "mode" => |c| c.mode.clone(),
        "pattern" => |c| c.pattern.clone(),
        "strategy" => |c| c.strategy.clone(),
        "sla" => |c| crate::util::json::Json::num(c.sla_s).to_string(),
        other => anyhow::bail!(
            "cannot group by {other:?} (have mode|pattern|strategy|sla)"),
    };
    let mut order: Vec<String> = Vec::new();
    for c in cells {
        let k = key(c);
        if !order.contains(&k) {
            order.push(k);
        }
    }
    let mut out = format!(
        "| {group} | cells | lat mean (s) | attain % | thr (rps) | \
         proc rate (rps) | GPU util % | swaps/cell |\n\
         |---|---|---|---|---|---|---|---|\n");
    for k in &order {
        let in_group = |c: &RunSummary| key(c) == *k;
        let n = cells.iter().filter(|c| in_group(c)).count();
        out.push_str(&format!(
            "| {} | {} | {:.2} | {:.1} | {:.2} | {:.2} | {:.1} | \
             {:.1} |\n",
            k, n,
            mean_where(cells, in_group, |c| c.latency_mean_s),
            mean_where(cells, in_group, |c| c.sla_attainment) * 100.0,
            mean_where(cells, in_group, |c| c.throughput_rps),
            mean_where(cells, in_group, |c| c.processing_rate_rps),
            mean_where(cells, in_group, |c| c.gpu_util) * 100.0,
            mean_where(cells, in_group, |c| c.swap_count as f64)));
    }
    Ok(out)
}

/// Baseline-vs-candidate comparison of two saved runs, matched by
/// cell label.  Seed replicas of one cell are folded first through
/// `lab::stats::aggregate` — the one group-by-label implementation —
/// so this table and the replica-stats table can never disagree.
pub fn compare_table(base: &[RunSummary], cand: &[RunSummary])
                     -> String {
    let b = crate::lab::stats::aggregate(base);
    let c = crate::lab::stats::aggregate(cand);
    let cand_by_label: std::collections::BTreeMap<&str,
                                                  &crate::lab::CellStats> =
        c.iter().map(|s| (s.label.as_str(), s)).collect();
    let base_labels: std::collections::BTreeSet<&str> =
        b.iter().map(|s| s.label.as_str()).collect();

    let mut out = String::from(
        "| cell | lat base->cand (s) | d lat % | attain base->cand \
         (%) | d pts | thr base->cand (rps) | d thr % |\n\
         |---|---|---|---|---|---|---|\n");
    let pct = |from: f64, to: f64| -> f64 {
        if from > 0.0 { (to - from) / from * 100.0 } else { 0.0 }
    };
    let mut missing = 0usize;
    for s in &b {
        let Some(cv) = cand_by_label.get(s.label.as_str()) else {
            missing += 1;
            continue;
        };
        let (bl, cl) = (s.latency_mean_s.mean, cv.latency_mean_s.mean);
        let (ba, ca) = (s.sla_attainment.mean, cv.sla_attainment.mean);
        let (bt, ct) = (s.throughput_rps.mean, cv.throughput_rps.mean);
        out.push_str(&format!(
            "| {} | {:.2}->{:.2} | {:+.1} | {:.1}->{:.1} | {:+.1} | \
             {:.2}->{:.2} | {:+.1} |\n",
            s.label, bl, cl, pct(bl, cl),
            ba * 100.0, ca * 100.0, (ca - ba) * 100.0,
            bt, ct, pct(bt, ct)));
    }
    let extra = c.iter()
        .filter(|s| !base_labels.contains(s.label.as_str())).count();
    if missing + extra > 0 {
        out.push_str(&format!(
            "\n{missing} baseline cell(s) missing from the candidate, \
             {extra} candidate cell(s) not in the baseline.\n"));
    }
    out
}

/// Mean of a metric across cells matching a predicate.
pub fn mean_where(cells: &[RunSummary], f: impl Fn(&RunSummary) -> bool,
                  metric: impl Fn(&RunSummary) -> f64) -> f64 {
    let vals: Vec<f64> = cells.iter().filter(|c| f(c)).map(metric)
        .collect();
    crate::util::mean(&vals)
}

/// The abstract's four headline comparisons, computed from a grid.
#[derive(Debug, Clone)]
pub struct HeadlineRatios {
    /// (No-CC latency − CC latency) / CC latency — paper: −20…−30 %.
    pub latency_delta_frac: f64,
    /// No-CC attainment − CC attainment, percentage points — paper:
    /// +15…20 points.
    pub sla_delta_points: f64,
    /// No-CC throughput / CC throughput − 1 — paper: +45…70 %.
    pub throughput_gain_frac: f64,
    /// No-CC GPU util / CC GPU util − 1 — paper: ≈ +50 %.
    pub util_gain_frac: f64,
    /// processing-rate ratio (No-CC / CC) — paper: ≈ 1.
    pub processing_rate_ratio: f64,
}

pub fn headline_ratios(cells: &[RunSummary]) -> HeadlineRatios {
    let cc = |c: &RunSummary| c.mode == "cc";
    let nocc = |c: &RunSummary| c.mode == "no-cc";
    let lat_cc = mean_where(cells, cc, |c| c.latency_mean_s);
    let lat_nocc = mean_where(cells, nocc, |c| c.latency_mean_s);
    let att_cc = mean_where(cells, cc, |c| c.sla_attainment);
    let att_nocc = mean_where(cells, nocc, |c| c.sla_attainment);
    let thr_cc = mean_where(cells, cc, |c| c.throughput_rps);
    let thr_nocc = mean_where(cells, nocc, |c| c.throughput_rps);
    let util_cc = mean_where(cells, cc, |c| c.gpu_util);
    let util_nocc = mean_where(cells, nocc, |c| c.gpu_util);
    let pr_cc = mean_where(cells, cc, |c| c.processing_rate_rps);
    let pr_nocc = mean_where(cells, nocc, |c| c.processing_rate_rps);
    HeadlineRatios {
        latency_delta_frac: if lat_cc > 0.0 {
            (lat_nocc - lat_cc) / lat_cc
        } else {
            0.0
        },
        sla_delta_points: (att_nocc - att_cc) * 100.0,
        throughput_gain_frac: if thr_cc > 0.0 {
            thr_nocc / thr_cc - 1.0
        } else {
            0.0
        },
        util_gain_frac: if util_cc > 0.0 {
            util_nocc / util_cc - 1.0
        } else {
            0.0
        },
        processing_rate_ratio: if pr_cc > 0.0 { pr_nocc / pr_cc } else { 0.0 },
    }
}

/// Render the headline comparison next to the paper's claims.
pub fn headline_table(h: &HeadlineRatios) -> String {
    format!(
        "| metric | paper (No-CC vs CC) | measured |\n|---|---|---|\n\
         | latency | 20–30% lower | {:.1}% {} |\n\
         | SLA attainment | 15–20 points higher | {:+.1} points |\n\
         | throughput | 45–70% higher | {:+.1}% |\n\
         | GPU utilization | ≈50% higher | {:+.1}% |\n\
         | processing rate | ≈ equal | ratio {:.2} |\n",
        h.latency_delta_frac.abs() * 100.0,
        if h.latency_delta_frac < 0.0 { "lower" } else { "higher" },
        h.sla_delta_points,
        h.throughput_gain_frac * 100.0,
        h.util_gain_frac * 100.0,
        h.processing_rate_ratio)
}

/// One abstract band checked against a measured grid (`lab check`).
#[derive(Debug, Clone)]
pub struct BandCheck {
    pub metric: &'static str,
    /// The abstract's claim, as text.
    pub band: &'static str,
    /// The measured figure, formatted.
    pub measured: String,
    pub in_band: bool,
}

/// The `paper-check` verdict: each of the abstract's four headline
/// bands — latency 20–30% lower, SLA attainment 15–20 points higher,
/// throughput 45–70% higher, GPU utilization ≈50% higher (we accept
/// ±15 points around 50) — tested against the measured ratios.
pub fn paper_check(h: &HeadlineRatios) -> Vec<BandCheck> {
    let lat = h.latency_delta_frac;
    vec![
        BandCheck {
            metric: "latency",
            band: "No-CC 20-30% lower",
            measured: format!(
                "{:.1}% {}", lat.abs() * 100.0,
                if lat < 0.0 { "lower" } else { "higher" }),
            in_band: (-0.30..=-0.20).contains(&lat),
        },
        BandCheck {
            metric: "SLA attainment",
            band: "No-CC 15-20 points higher",
            measured: format!("{:+.1} points", h.sla_delta_points),
            in_band: (15.0..=20.0).contains(&h.sla_delta_points),
        },
        BandCheck {
            metric: "throughput",
            band: "No-CC 45-70% higher",
            measured: format!("{:+.1}%",
                              h.throughput_gain_frac * 100.0),
            in_band: (0.45..=0.70).contains(&h.throughput_gain_frac),
        },
        BandCheck {
            metric: "GPU utilization",
            band: "No-CC ~50% higher (35-65 accepted)",
            measured: format!("{:+.1}%", h.util_gain_frac * 100.0),
            in_band: (0.35..=0.65).contains(&h.util_gain_frac),
        },
    ]
}

/// Render band checks as a markdown verdict table.
pub fn band_table(checks: &[BandCheck]) -> String {
    let mut out = String::from(
        "| metric | paper band | measured | verdict |\n\
         |---|---|---|---|\n");
    for c in checks {
        out.push_str(&format!(
            "| {} | {} | {} | {} |\n", c.metric, c.band, c.measured,
            if c.in_band { "in band" } else { "OUT OF BAND" }));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(mode: &str, lat: f64, att: f64, thr: f64, util: f64)
            -> RunSummary {
        RunSummary {
            label: "t".into(),
            mode: mode.into(),
            pattern: "gamma".into(),
            strategy: "best-batch".into(),
            sla_s: 6.0,
            mean_rps: 4.0,
            duration_s: 60.0,
            runtime_s: 60.0,
            generated: 240,
            completed: 200,
            sla_met: (att * 240.0) as u64,
            sla_attainment: att,
            latency_mean_s: lat,
            latency_p50_s: lat,
            latency_p90_s: lat * 1.5,
            latency_p99_s: lat * 2.0,
            latency_max_s: lat * 3.0,
            throughput_rps: thr,
            processing_rate_rps: 30.0,
            gpu_util: util,
            swap_count: 12,
            total_load_s: 10.0,
            total_unload_s: 0.1,
            total_exec_s: 20.0,
            total_crypto_s: 1.0,
            mean_load_s: 0.8,
            ..RunSummary::default()
        }
    }

    #[test]
    fn ratios_match_construction() {
        let cells = vec![
            cell("cc", 4.0, 0.5, 2.0, 0.2),
            cell("no-cc", 3.0, 0.7, 3.2, 0.3),
        ];
        let h = headline_ratios(&cells);
        assert!((h.latency_delta_frac - (-0.25)).abs() < 1e-9);
        assert!((h.sla_delta_points - 20.0).abs() < 1e-9);
        assert!((h.throughput_gain_frac - 0.6).abs() < 1e-9);
        assert!((h.util_gain_frac - 0.5).abs() < 1e-9);
        assert!((h.processing_rate_ratio - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tables_render() {
        let cells = vec![cell("cc", 4.0, 0.5, 2.0, 0.2)];
        let t = cells_table(&cells);
        assert!(t.contains("| cc | gamma |"));
        let h = headline_table(&headline_ratios(&cells));
        assert!(h.contains("latency"));
    }

    #[test]
    fn paper_check_bands() {
        let in_band = headline_ratios(&[
            cell("cc", 4.0, 0.5, 2.0, 0.2),
            cell("no-cc", 3.0, 0.68, 3.2, 0.3),
        ]);
        let checks = paper_check(&in_band);
        assert_eq!(checks.len(), 4);
        assert!(checks.iter().all(|c| c.in_band),
                "{:?}", checks.iter().map(|c| (&c.metric, c.in_band))
                    .collect::<Vec<_>>());
        // identical modes -> every delta is 0 -> all out of band
        let flat = headline_ratios(&[
            cell("cc", 4.0, 0.5, 2.0, 0.2),
            cell("no-cc", 4.0, 0.5, 2.0, 0.2),
        ]);
        let checks = paper_check(&flat);
        assert!(checks.iter().all(|c| !c.in_band));
        let t = band_table(&checks);
        assert!(t.contains("OUT OF BAND"), "{t}");
    }

    #[test]
    fn per_device_only_renders_fleet_cells() {
        let mut fleet = cell("cc", 4.0, 0.5, 2.0, 0.2);
        fleet.label = "fleet".into();
        fleet.devices = 2;
        fleet.per_device = vec![
            crate::engine::DeviceSummary {
                device: 0, mode: "cc".into(), batches: 3,
                ..Default::default()
            },
            crate::engine::DeviceSummary {
                device: 1, mode: "no-cc".into(), batches: 5,
                ..Default::default()
            },
        ];
        let single = cell("cc", 4.0, 0.5, 2.0, 0.2);
        let t = per_device_table(&[single, fleet]);
        assert!(t.contains("| fleet | 0 | cc |"), "{t}");
        assert!(t.contains("| fleet | 1 | no-cc |"), "{t}");
        assert_eq!(t.matches("| t |").count(), 0,
                   "single-device cells contribute no rows");
    }

    #[test]
    fn data_path_table_skips_cells_without_data_crypto() {
        let plain = cell("no-cc", 3.0, 0.7, 3.2, 0.3);
        let mut io = cell("cc", 4.0, 0.5, 2.0, 0.2);
        io.label = "cc_io".into();
        io.completed = 200;
        io.runtime_s = 60.0;
        io.devices = 1;
        io.total_data_crypto_s = 1.2;
        io.total_data_crypto_exposed_s = 0.3;
        io.data_bytes = 2_000_000;
        io.data_wire_bytes = 2_160_000;
        assert!(!has_data_path(&[plain.clone()]));
        assert!(has_data_path(&[plain.clone(), io.clone()]));
        let t = data_path_table(&[plain, io]);
        assert!(t.contains("| cc_io | cc | 2.000 | 1.080x | 1.200 | \
                            0.300 |"), "{t}");
        // 1.2 s over 200 requests = 6 ms/req; 1.2/60 = 2% of runtime
        assert!(t.contains("| 6.000 | 2.00 |"), "{t}");
        assert_eq!(t.matches("no-cc").count(), 0,
                   "cells without data crypto contribute no rows");
    }

    #[test]
    fn tenancy_table_renders_only_tenancy_cells() {
        let plain = cell("no-cc", 3.0, 0.7, 3.2, 0.3);
        let mut mt = cell("cc", 4.0, 0.5, 2.0, 0.2);
        mt.label = "cc_mt".into();
        mt.tenancy = Some(crate::engine::TenancySummary {
            admission: "class-weighted".into(),
            shed_total: 14,
            goodput_rps: 1.75,
            fairness: 0.912,
            classes: vec![
                crate::engine::ClassSummary {
                    name: "gold".into(), generated: 40, completed: 38,
                    met: 36, shed: 1, expired: 1, attainment: 0.9,
                },
                crate::engine::ClassSummary {
                    name: "silver".into(), generated: 60, completed: 50,
                    met: 45, shed: 4, expired: 6, attainment: 0.75,
                },
                crate::engine::ClassSummary {
                    name: "free".into(), generated: 100, completed: 80,
                    met: 60, shed: 9, expired: 11, attainment: 0.6,
                },
            ],
            churn_by_model: vec![("cat-00".into(), 2),
                                 ("cat-01".into(), 7)],
        });
        assert!(!has_tenancy(&[plain.clone()]));
        assert!(has_tenancy(&[plain.clone(), mt.clone()]));
        let t = tenancy_table(&[plain, mt]);
        // 1/40, 4/60, 9/100 shed; cat-01 is the churn leader
        assert!(t.contains(
            "| cc_mt | class-weighted | 14 | 1.75 | 0.912 | 2.5 | \
             6.7 | 9.0 | cat-01 x7 |"), "{t}");
        assert_eq!(t.matches("no-cc").count(), 0,
                   "cells without a tenancy block contribute no rows");
    }

    #[test]
    fn hw_gen_table_groups_profiles_and_splits_the_tax() {
        let plain = cell("cc", 4.0, 0.5, 2.0, 0.2);
        assert!(!has_profiles(&[plain.clone()]),
                "profile-free cells must not trigger the table");
        let mk = |label: &str, mode: &str, lat: f64, att: f64,
                  crypto: f64, bridge: f64| {
            let mut c = cell(mode, lat, att, 2.0, 0.2);
            c.label = label.into();
            c.total_crypto_s = crypto;
            c.total_bridge_s = bridge;
            c
        };
        let cells = vec![
            mk("no-cc_g_prof-h100-cc", "no-cc", 3.0, 0.7, 0.0, 0.0),
            mk("cc_g_prof-h100-cc", "cc", 4.5, 0.5, 6.0, 0.0),
            mk("no-cc_g_prof-gh200-coherent", "no-cc", 3.0, 0.7,
               0.0, 0.0),
            mk("cc_g_prof-gh200-coherent", "cc", 3.3, 0.68, 0.0, 1.5),
        ];
        assert!(has_profiles(&cells));
        let t = hw_gen_table(&cells);
        // Hopper: +50% latency gap, tax 100% chunk crypto
        assert!(t.contains(
            "| h100-cc | 2 | 3.00 | 4.50 | +50.0 | +20.0 | 6.00 | \
             0.00 | 100.0 | 0.0 |"), "{t}");
        // coherent: small gap, tax 100% bridge residual
        assert!(t.contains(
            "| gh200-coherent | 2 | 3.00 | 3.30 | +10.0 | +2.0 | \
             0.00 | 1.50 | 0.0 | 100.0 |"), "{t}");
    }

    #[test]
    fn waterfall_table_renders_traced_cells_and_names_the_tax_driver() {
        let plain = cell("cc", 4.0, 0.5, 2.0, 0.2);
        assert!(!has_waterfall(&[plain.clone()]),
                "untraced cells must not trigger the table");
        let mk = |label: &str, mode: &str, queue: f64, load: f64,
                  bridge: f64, crypto: f64| {
            let mut c = cell(mode, 2.0, 0.6, 2.0, 0.2);
            c.label = label.into();
            // totals over 100 requests; phases sum to the latency
            c.phase_totals = Some(crate::obs::PhaseTotals {
                requests: 100,
                queue_wait_s: queue,
                swap_unload_s: 1.0,
                swap_load_s: load,
                swap_bridge_s: bridge,
                swap_crypto_exposed_s: crypto,
                exec_s: 100.0,
                io_s: 10.0,
                activation_io_s: 0.0,
                latency_s: queue + 1.0 + load + 100.0 + 10.0,
                queue_wait_p95_s: 0.9,
                swap_load_p95_s: 1.8,
                exec_p95_s: 1.1,
            });
            c
        };
        let cells = vec![
            mk("no-cc_g_prof-h100-cc", "no-cc", 50.0, 40.0, 0.0, 0.0),
            mk("cc_g_prof-h100-cc", "cc", 100.0, 140.0, 20.0, 60.0),
            plain,
        ];
        assert!(has_waterfall(&cells));
        let t = waterfall_table(&cells);
        // per-cell rows: mean s/request per phase
        assert!(t.contains(
            "| cc_g_prof-h100-cc | cc | 100 | 1.000 | 0.900 | 0.010 | \
             1.400 | 0.200 | 0.600 | 1.800 | 1.000 | 1.100 | 0.100 | \
             3.510 |"), "{t}");
        assert!(t.contains(
            "| no-cc_g_prof-h100-cc | no-cc | 100 | 0.500 |"), "{t}");
        // CC-minus-No-CC deltas: queue +0.5, swap +1.0, exec/io flat,
        // latency +1.5 — the swap phase pays the tax
        assert!(t.contains(
            "| h100-cc | +0.500 | +1.000 | +0.000 | +0.000 | +1.500 | \
             swap |"), "{t}");
        // the untraced cell contributes no row
        assert_eq!(t.matches("| t |").count(), 0, "{t}");
    }

    #[test]
    fn pipeline_table_scales_the_tax_with_stage_count() {
        let plain = cell("cc", 4.0, 0.5, 2.0, 0.2);
        assert!(!has_pipeline(&[plain.clone()]),
                "stage-free grids must not trigger the table");
        let mk = |label: &str, mode: &str, stages: usize, lat: f64| {
            let mut c = cell(mode, lat, 0.5, 2.0, 0.2);
            c.label = label.into();
            c.pp_stages = stages;
            if stages > 1 && mode == "cc" {
                c.ttft_mean_s = 0.8;
                c.token_throughput_tps = 128.0;
                c.total_bubble_s = 3.0;
                c.activation_wire_bytes = 2_000_000;
                c.total_activation_crypto_s = 1.5;
                c.total_activation_crypto_exposed_s = 0.25;
            }
            c
        };
        let cells = vec![
            mk("no-cc_g_prof-h100-cc", "no-cc", 1, 3.0),
            mk("cc_g_prof-h100-cc", "cc", 1, 4.5),
            mk("no-cc_g_prof-h100-cc_pp2", "no-cc", 2, 3.0),
            mk("cc_g_prof-h100-cc_pp2", "cc", 2, 6.0),
        ];
        assert!(has_pipeline(&cells));
        let t = pipeline_table(&cells);
        // stage 1 baseline: +50% tax, no pipeline signature
        assert!(t.contains(
            "| h100-cc | 1 | 3.00 | 4.50 | +50.0 | 0.00 | 0.0 | \
             0.00 | 0.000 | 0.000 | 0.000 |"), "{t}");
        // stage 2: tax doubles; sealed activation traffic shows up
        assert!(t.contains(
            "| h100-cc | 2 | 3.00 | 6.00 | +100.0 | 0.80 | 128.0 | \
             3.00 | 2.000 | 1.500 | 0.250 |"), "{t}");
    }

    #[test]
    fn grouped_table_groups_by_axis() {
        let cells = vec![
            cell("cc", 4.0, 0.5, 2.0, 0.2),
            cell("cc", 6.0, 0.3, 1.0, 0.1),
            cell("no-cc", 3.0, 0.7, 3.2, 0.3),
        ];
        let t = grouped_table(&cells, "mode").unwrap();
        assert!(t.contains("| cc | 2 | 5.00 |"), "{t}");
        assert!(t.contains("| no-cc | 1 | 3.00 |"), "{t}");
        assert!(grouped_table(&cells, "color").is_err());
    }

    #[test]
    fn compare_matches_labels_and_averages_replicas() {
        let mut b1 = cell("cc", 4.0, 0.5, 2.0, 0.2);
        b1.label = "x".into();
        let mut b2 = cell("cc", 6.0, 0.5, 4.0, 0.2);
        b2.label = "x".into();
        let mut c1 = cell("cc", 4.0, 0.6, 3.3, 0.2);
        c1.label = "x".into();
        let mut orphan = cell("cc", 1.0, 0.1, 1.0, 0.1);
        orphan.label = "gone".into();
        let t = compare_table(&[b1, b2, orphan], &[c1]);
        // baseline replicas average to lat 5.0, thr 3.0
        assert!(t.contains("| x | 5.00->4.00 | -20.0 |"), "{t}");
        assert!(t.contains("| 3.00->3.30 | +10.0 |"), "{t}");
        assert!(t.contains("1 baseline cell(s) missing"), "{t}");
    }
}
