//! Paper-style report rendering from run summaries.
//!
//! Turns a set of `RunSummary` cells into the tables behind Fig 5/6/7
//! and the abstract's headline ratios, as markdown.

use crate::engine::RunSummary;

/// Render a markdown table of the given summaries, one row per cell.
pub fn cells_table(cells: &[RunSummary]) -> String {
    let mut out = String::from(
        "| mode | pattern | strategy | SLA (s) | gen | done | attain % | \
         lat mean (s) | lat p99 (s) | thr (rps) | proc rate (rps) | \
         GPU util % | swaps |\n|---|---|---|---|---|---|---|---|---|---|---|---|---|\n");
    for c in cells {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {:.1} | {:.2} | {:.2} | \
             {:.2} | {:.2} | {:.1} | {} |\n",
            c.mode, c.pattern, c.strategy, c.sla_s, c.generated,
            c.completed, c.sla_attainment * 100.0, c.latency_mean_s,
            c.latency_p99_s, c.throughput_rps, c.processing_rate_rps,
            c.gpu_util * 100.0, c.swap_count));
    }
    out
}

/// Mean of a metric across cells matching a predicate.
pub fn mean_where(cells: &[RunSummary], f: impl Fn(&RunSummary) -> bool,
                  metric: impl Fn(&RunSummary) -> f64) -> f64 {
    let vals: Vec<f64> = cells.iter().filter(|c| f(c)).map(metric)
        .collect();
    crate::util::mean(&vals)
}

/// The abstract's four headline comparisons, computed from a grid.
#[derive(Debug, Clone)]
pub struct HeadlineRatios {
    /// (No-CC latency − CC latency) / CC latency — paper: −20…−30 %.
    pub latency_delta_frac: f64,
    /// No-CC attainment − CC attainment, percentage points — paper:
    /// +15…20 points.
    pub sla_delta_points: f64,
    /// No-CC throughput / CC throughput − 1 — paper: +45…70 %.
    pub throughput_gain_frac: f64,
    /// No-CC GPU util / CC GPU util − 1 — paper: ≈ +50 %.
    pub util_gain_frac: f64,
    /// processing-rate ratio (No-CC / CC) — paper: ≈ 1.
    pub processing_rate_ratio: f64,
}

pub fn headline_ratios(cells: &[RunSummary]) -> HeadlineRatios {
    let cc = |c: &RunSummary| c.mode == "cc";
    let nocc = |c: &RunSummary| c.mode == "no-cc";
    let lat_cc = mean_where(cells, cc, |c| c.latency_mean_s);
    let lat_nocc = mean_where(cells, nocc, |c| c.latency_mean_s);
    let att_cc = mean_where(cells, cc, |c| c.sla_attainment);
    let att_nocc = mean_where(cells, nocc, |c| c.sla_attainment);
    let thr_cc = mean_where(cells, cc, |c| c.throughput_rps);
    let thr_nocc = mean_where(cells, nocc, |c| c.throughput_rps);
    let util_cc = mean_where(cells, cc, |c| c.gpu_util);
    let util_nocc = mean_where(cells, nocc, |c| c.gpu_util);
    let pr_cc = mean_where(cells, cc, |c| c.processing_rate_rps);
    let pr_nocc = mean_where(cells, nocc, |c| c.processing_rate_rps);
    HeadlineRatios {
        latency_delta_frac: if lat_cc > 0.0 {
            (lat_nocc - lat_cc) / lat_cc
        } else {
            0.0
        },
        sla_delta_points: (att_nocc - att_cc) * 100.0,
        throughput_gain_frac: if thr_cc > 0.0 {
            thr_nocc / thr_cc - 1.0
        } else {
            0.0
        },
        util_gain_frac: if util_cc > 0.0 {
            util_nocc / util_cc - 1.0
        } else {
            0.0
        },
        processing_rate_ratio: if pr_cc > 0.0 { pr_nocc / pr_cc } else { 0.0 },
    }
}

/// Render the headline comparison next to the paper's claims.
pub fn headline_table(h: &HeadlineRatios) -> String {
    format!(
        "| metric | paper (No-CC vs CC) | measured |\n|---|---|---|\n\
         | latency | 20–30% lower | {:.1}% {} |\n\
         | SLA attainment | 15–20 points higher | {:+.1} points |\n\
         | throughput | 45–70% higher | {:+.1}% |\n\
         | GPU utilization | ≈50% higher | {:+.1}% |\n\
         | processing rate | ≈ equal | ratio {:.2} |\n",
        h.latency_delta_frac.abs() * 100.0,
        if h.latency_delta_frac < 0.0 { "lower" } else { "higher" },
        h.sla_delta_points,
        h.throughput_gain_frac * 100.0,
        h.util_gain_frac * 100.0,
        h.processing_rate_ratio)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(mode: &str, lat: f64, att: f64, thr: f64, util: f64)
            -> RunSummary {
        RunSummary {
            label: "t".into(),
            mode: mode.into(),
            pattern: "gamma".into(),
            strategy: "best-batch".into(),
            sla_s: 6.0,
            mean_rps: 4.0,
            duration_s: 60.0,
            runtime_s: 60.0,
            generated: 240,
            completed: 200,
            sla_met: (att * 240.0) as u64,
            sla_attainment: att,
            latency_mean_s: lat,
            latency_p50_s: lat,
            latency_p90_s: lat * 1.5,
            latency_p99_s: lat * 2.0,
            latency_max_s: lat * 3.0,
            throughput_rps: thr,
            processing_rate_rps: 30.0,
            gpu_util: util,
            swap_count: 12,
            total_load_s: 10.0,
            total_unload_s: 0.1,
            total_exec_s: 20.0,
            total_crypto_s: 1.0,
            mean_load_s: 0.8,
            ..RunSummary::default()
        }
    }

    #[test]
    fn ratios_match_construction() {
        let cells = vec![
            cell("cc", 4.0, 0.5, 2.0, 0.2),
            cell("no-cc", 3.0, 0.7, 3.2, 0.3),
        ];
        let h = headline_ratios(&cells);
        assert!((h.latency_delta_frac - (-0.25)).abs() < 1e-9);
        assert!((h.sla_delta_points - 20.0).abs() < 1e-9);
        assert!((h.throughput_gain_frac - 0.6).abs() < 1e-9);
        assert!((h.util_gain_frac - 0.5).abs() < 1e-9);
        assert!((h.processing_rate_ratio - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tables_render() {
        let cells = vec![cell("cc", 4.0, 0.5, 2.0, 0.2)];
        let t = cells_table(&cells);
        assert!(t.contains("| cc | gamma |"));
        let h = headline_table(&headline_ratios(&cells));
        assert!(h.contains("latency"));
    }
}
