//! Per-run recording: the paper's three CSV classes (§III-B) plus the
//! aggregated `RunSummary`.
//!
//! * `<label>_requests.csv` — request-level details: arrival, exec
//!   start, completion, model, batch size, latency, SLA flag.
//! * `<label>_batches.csv` — batch/throughput details: load/unload/exec
//!   times, swap flag, rows, artifact batch.
//! * `<label>_monitor.csv` — system monitoring: CPU/RSS/ctxt switches,
//!   sim-GPU occupancy/memory/fragmentation/DMA counters.

use std::path::Path;

use crate::coordinator::request::CompletedRequest;
use crate::metrics::hist::Histogram;
use crate::metrics::system::ProcSample;
use crate::runtime::{ModelId, ModelTable};
use crate::util::csvio::CsvWriter;

/// One executed batch.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    pub at_s: f64,
    /// Interned model id; resolved back to its name at CSV-write time
    /// (the hot loop records a `Copy` id, never a `String` clone).
    pub model: ModelId,
    /// Fleet device the batch executed on.
    pub device: usize,
    pub rows: usize,
    pub artifact_batch: usize,
    pub swapped: bool,
    /// The swap promoted a prefetched buffer (no DMA paid).
    pub promoted: bool,
    pub load_s: f64,
    pub unload_s: f64,
    pub exec_s: f64,
    pub io_s: f64,
    /// Payload bytes of this batch priced by the inference data path
    /// (`--data-path on`; 0 when off).
    pub data_bytes: u64,
    /// Data-path bytes on the link including per-chunk AEAD framing.
    pub data_wire_bytes: u64,
    /// Total modeled seal/open work of this batch's payload I/O.
    pub data_crypto_s: f64,
    /// Payload crypto not hidden behind the link (== total when the
    /// chunk pipeline is off).
    pub data_crypto_exposed_s: f64,
    /// Decrypt-ahead staging issued after this batch's dispatch,
    /// overlapped with its execution.
    pub prefetch_s: f64,
}

/// One monitor sample (process + one fleet device).
#[derive(Debug, Clone)]
pub struct MonitorRecord {
    pub proc: ProcSample,
    /// Fleet device this sample describes.
    pub device: usize,
    pub gpu_util: f64,
    pub mem_in_use: u64,
    pub mem_peak: u64,
    pub fragmentation: f64,
    pub dma_h2d_bytes: u64,
    /// Total modeled crypto work so far (see `gpu::dma::DmaStats`).
    pub dma_crypto_total_s: f64,
    /// Crypto time not hidden behind the DMA pipeline.
    pub dma_crypto_exposed_s: f64,
    pub swaps: u64,
}

/// Collects everything during a run.
#[derive(Default)]
pub struct Recorder {
    pub requests: Vec<(CompletedRequest, bool)>,
    pub batches: Vec<BatchRecord>,
    pub monitor: Vec<MonitorRecord>,
    pub latency_hist: Histogram,
    /// Structured event trace (`--trace events|full`); `None` — and
    /// therefore zero bytes of output anywhere — when tracing is off.
    /// The engine owns the recording (see `engine::run`); the trace
    /// rides here so it reaches the write-out and the summary with the
    /// rest of the run's records.
    pub trace: Option<crate::obs::Trace>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn on_complete(&mut self, c: CompletedRequest, sla_met: bool) {
        self.latency_hist.record(c.latency_s());
        self.requests.push((c, sla_met));
    }

    pub fn on_batch(&mut self, b: BatchRecord) {
        self.batches.push(b);
    }

    pub fn on_monitor(&mut self, m: MonitorRecord) {
        self.monitor.push(m);
    }

    /// Total time spent executing batches, summed over all devices.
    pub fn exec_busy_s(&self) -> f64 {
        self.batches.iter().map(|b| b.exec_s).sum()
    }

    /// Time spent executing batches on one fleet device.
    pub fn exec_busy_s_for(&self, device: usize) -> f64 {
        self.batches.iter().filter(|b| b.device == device)
            .map(|b| b.exec_s).sum()
    }

    pub fn total_load_s(&self) -> f64 {
        self.batches.iter().map(|b| b.load_s).sum()
    }

    /// Write the three CSV classes.  `table` resolves interned ids
    /// back to model names; writers are pre-sized by row count so bulk
    /// dumps stream through a right-sized buffer.
    pub fn write_csvs(&self, dir: &Path, label: &str,
                      table: &ModelTable) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        // ~96 bytes/row is a comfortable over-estimate for every table
        let cap = |rows: usize| (rows.max(64) * 96).min(1 << 22);

        let mut w = CsvWriter::create_with_capacity(
            &dir.join(format!("{label}_requests.csv")),
            &["id", "model", "device", "arrival_s", "exec_start_s",
              "complete_s", "latency_s", "batch", "batch_rows",
              "caused_swap", "sla_met"],
            cap(self.requests.len()))?;
        for (c, met) in &self.requests {
            w.row(&[c.id.to_string(), table.name(c.model).to_string(),
                    c.device.to_string(),
                    fmt(c.arrival_s), fmt(c.exec_start_s),
                    fmt(c.complete_s), fmt(c.latency_s()),
                    c.batch.to_string(), c.batch_rows.to_string(),
                    c.caused_swap.to_string(), met.to_string()])?;
        }
        w.flush()?;

        let mut w = CsvWriter::create_with_capacity(
            &dir.join(format!("{label}_batches.csv")),
            &["at_s", "model", "device", "rows", "artifact_batch",
              "swapped", "promoted", "load_s", "unload_s", "exec_s",
              "io_s", "data_bytes", "data_wire_bytes", "data_crypto_s",
              "data_crypto_exposed_s", "prefetch_s"],
            cap(self.batches.len()))?;
        for b in &self.batches {
            w.row(&[fmt(b.at_s), table.name(b.model).to_string(),
                    b.device.to_string(),
                    b.rows.to_string(),
                    b.artifact_batch.to_string(), b.swapped.to_string(),
                    b.promoted.to_string(),
                    fmt(b.load_s), fmt(b.unload_s), fmt(b.exec_s),
                    fmt(b.io_s), b.data_bytes.to_string(),
                    b.data_wire_bytes.to_string(), fmt(b.data_crypto_s),
                    fmt(b.data_crypto_exposed_s), fmt(b.prefetch_s)])?;
        }
        w.flush()?;

        let mut w = CsvWriter::create_with_capacity(
            &dir.join(format!("{label}_monitor.csv")),
            &["at_s", "device", "cpu_user_s", "cpu_sys_s", "rss_bytes",
              "vol_ctxt", "invol_ctxt", "gpu_util", "mem_in_use",
              "mem_peak", "fragmentation", "dma_h2d_bytes",
              "dma_crypto_total_s", "dma_crypto_exposed_s", "swaps"],
            cap(self.monitor.len()))?;
        for m in &self.monitor {
            w.row(&[fmt(m.proc.at_s), m.device.to_string(),
                    fmt(m.proc.cpu_user_s),
                    fmt(m.proc.cpu_sys_s), m.proc.rss_bytes.to_string(),
                    m.proc.vol_ctxt.to_string(),
                    m.proc.invol_ctxt.to_string(), fmt(m.gpu_util),
                    m.mem_in_use.to_string(), m.mem_peak.to_string(),
                    fmt(m.fragmentation), m.dma_h2d_bytes.to_string(),
                    fmt(m.dma_crypto_total_s), fmt(m.dma_crypto_exposed_s),
                    m.swaps.to_string()])?;
        }
        w.flush()?;
        Ok(())
    }
}

fn fmt(v: f64) -> String {
    format!("{v:.6}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::csvio::CsvTable;

    fn completed(id: u64, latency: f64) -> CompletedRequest {
        CompletedRequest {
            id,
            model: ModelId(0),
            arrival_s: 1.0,
            exec_start_s: 1.0 + latency * 0.7,
            complete_s: 1.0 + latency,
            batch: 4,
            batch_rows: 3,
            caused_swap: false,
            device: 0,
        }
    }

    #[test]
    fn csvs_roundtrip() {
        let mut r = Recorder::new();
        r.on_complete(completed(1, 0.5), true);
        r.on_complete(completed(2, 7.5), false);
        r.on_batch(BatchRecord {
            at_s: 2.0, model: ModelId(0), device: 1, rows: 3,
            artifact_batch: 4, swapped: true, promoted: false,
            load_s: 0.4, unload_s: 0.01, exec_s: 0.2, io_s: 0.005,
            data_bytes: 792, data_wire_bytes: 872,
            data_crypto_s: 0.002, data_crypto_exposed_s: 0.001,
            prefetch_s: 0.15,
        });
        r.on_monitor(MonitorRecord {
            proc: ProcSample { at_s: 2.5, ..Default::default() },
            device: 1,
            gpu_util: 0.3, mem_in_use: 100, mem_peak: 200,
            fragmentation: 0.0, dma_h2d_bytes: 1000,
            dma_crypto_total_s: 0.1, dma_crypto_exposed_s: 0.04,
            swaps: 1,
        });

        let dir = std::env::temp_dir().join("sincere_rec_test");
        let table = ModelTable::new(["llama-sim"]);
        r.write_csvs(&dir, "t", &table).unwrap();

        let reqs = CsvTable::read(&dir.join("t_requests.csv")).unwrap();
        assert_eq!(reqs.rows.len(), 2);
        let lat = reqs.f64_col("latency_s").unwrap();
        assert!((lat[0] - 0.5).abs() < 1e-6);
        assert_eq!(reqs.rows[1][reqs.col("sla_met").unwrap()], "false");

        let batches = CsvTable::read(&dir.join("t_batches.csv")).unwrap();
        assert_eq!(batches.rows.len(), 1);
        let mon = CsvTable::read(&dir.join("t_monitor.csv")).unwrap();
        assert_eq!(mon.rows.len(), 1);

        assert!((r.exec_busy_s() - 0.2).abs() < 1e-12);
        assert!((r.exec_busy_s_for(1) - 0.2).abs() < 1e-12);
        assert_eq!(r.exec_busy_s_for(0), 0.0);
        assert!((r.total_load_s() - 0.4).abs() < 1e-12);
        assert_eq!(r.latency_hist.count(), 2);
        assert_eq!(batches.rows[0][batches.col("device").unwrap()], "1");
        assert_eq!(batches.rows[0][batches.col("promoted").unwrap()],
                   "false");
        let pf = batches.f64_col("prefetch_s").unwrap();
        assert!((pf[0] - 0.15).abs() < 1e-6);
        assert_eq!(batches.rows[0][batches.col("data_bytes").unwrap()],
                   "792");
        assert_eq!(batches.rows[0][batches.col("data_wire_bytes")
                                   .unwrap()], "872");
        let dc = batches.f64_col("data_crypto_s").unwrap();
        assert!((dc[0] - 0.002).abs() < 1e-9);
        let dce = batches.f64_col("data_crypto_exposed_s").unwrap();
        assert!((dce[0] - 0.001).abs() < 1e-9);
        let exposed = mon.f64_col("dma_crypto_exposed_s").unwrap();
        assert!((exposed[0] - 0.04).abs() < 1e-6);
    }
}
