//! Measurement: latency histograms, per-run recorders (the paper's three
//! CSV classes, §III-B), system monitoring, and report rendering.

pub mod hist;
pub mod recorder;
pub mod report;
pub mod system;
