//! Log-bucketed latency histogram (HdrHistogram-lite).
//!
//! Fixed memory, ~2.4% relative bucket error: buckets are geometric with
//! ratio 2^(1/16) starting at 10 µs.  Quantiles interpolate inside the
//! winning bucket.  Exact min/max/sum are tracked separately so mean and
//! extremes are error-free.

const BASE: f64 = 10e-6; // 10 µs
const RATIO_LOG2: f64 = 1.0 / 16.0; // 16 buckets per octave
const NBUCKETS: usize = 512; // covers 10 µs .. ~47 000 s

/// Histogram over seconds.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; NBUCKETS],
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket(v: f64) -> usize {
        if v <= BASE {
            return 0;
        }
        let b = ((v / BASE).log2() / RATIO_LOG2).floor() as usize;
        b.min(NBUCKETS - 1)
    }

    /// Lower edge of bucket i, seconds.
    fn edge(i: usize) -> f64 {
        BASE * 2f64.powf(i as f64 * RATIO_LOG2)
    }

    pub fn record(&mut self, v_secs: f64) {
        assert!(v_secs.is_finite() && v_secs >= 0.0,
                "bad latency {v_secs}");
        self.counts[Self::bucket(v_secs)] += 1;
        self.n += 1;
        self.sum += v_secs;
        self.min = self.min.min(v_secs);
        self.max = self.max.max(v_secs);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    /// Approximate quantile, q in [0,1]; exact at the extremes.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if q <= 0.0 {
            return self.min();
        }
        if q >= 1.0 {
            return self.max();
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                // interpolate within the bucket, clamped to observed range
                let lo = Self::edge(i);
                let hi = Self::edge(i + 1);
                let mid = (lo + hi) / 2.0;
                return mid.clamp(self.min(), self.max());
            }
        }
        self.max()
    }

    /// Fraction of samples at or below `threshold_s` — SLA attainment.
    pub fn fraction_le(&self, threshold_s: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        // conservative: whole buckets strictly below + the threshold's
        // own bucket counts as attained only up to its lower edge rule.
        let b = Self::bucket(threshold_s);
        let below: u64 = self.counts[..=b].iter().sum();
        below as f64 / self.n as f64
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn mean_min_max_exact() {
        let mut h = Histogram::new();
        for v in [0.1, 0.2, 0.3] {
            h.record(v);
        }
        assert!((h.mean() - 0.2).abs() < 1e-12);
        assert_eq!(h.min(), 0.1);
        assert_eq!(h.max(), 0.3);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let mut h = Histogram::new();
        // uniform grid 1ms..1s
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        for (q, want) in [(0.5, 0.5), (0.9, 0.9), (0.99, 0.99)] {
            let got = h.quantile(q);
            let rel = (got - want).abs() / want;
            assert!(rel < 0.05, "q{q}: got {got} want {want}");
        }
    }

    #[test]
    fn fraction_le_tracks_sla() {
        let mut h = Histogram::new();
        for i in 0..100 {
            h.record(i as f64 * 0.1); // 0 .. 9.9s
        }
        let att = h.fraction_le(4.0);
        assert!((att - 0.41).abs() < 0.05, "attainment {att}");
        assert_eq!(h.fraction_le(100.0), 1.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(0.1);
        b.record(0.4);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), 0.4);
    }

    #[test]
    #[should_panic(expected = "bad latency")]
    fn rejects_negative() {
        Histogram::new().record(-1.0);
    }

    #[test]
    fn extreme_values_clamped_to_buckets() {
        let mut h = Histogram::new();
        h.record(1e-9); // below base
        h.record(1e9); // beyond last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) <= h.quantile(1.0));
    }

    use crate::prop_assert;
    use crate::util::prop::{forall, Gen};

    fn sample(g: &mut Gen) -> Vec<f64> {
        g.vec(64, |g| g.f64_in(0.0, 40.0))
    }

    /// Merging N split histograms is indistinguishable — count, sum,
    /// min, max, mean — from recording every value into one histogram,
    /// because `merge` adds the same bucket counts record() would
    /// have placed (the trace layer leans on this when it aggregates
    /// per-phase histograms across lab cells).
    #[test]
    fn prop_merge_matches_single_recording() {
        forall("merge == single recording", 200, |g| {
            let xs = sample(g);
            let ys = sample(g);
            let mut whole = Histogram::new();
            for v in xs.iter().chain(&ys) {
                whole.record(*v);
            }
            let mut a = Histogram::new();
            let mut b = Histogram::new();
            for v in &xs {
                a.record(*v);
            }
            for v in &ys {
                b.record(*v);
            }
            a.merge(&b);
            prop_assert!(a.count() == whole.count(),
                         "count {} != {}", a.count(), whole.count());
            prop_assert!((a.sum - whole.sum).abs() <= 1e-9,
                         "sum {} != {}", a.sum, whole.sum);
            prop_assert!((a.mean() - whole.mean()).abs() <= 1e-9,
                         "mean {} != {}", a.mean(), whole.mean());
            prop_assert!(a.min() == whole.min() && a.max() == whole.max(),
                         "extremes ({}, {}) != ({}, {})",
                         a.min(), a.max(), whole.min(), whole.max());
            // same buckets -> same quantiles, exactly
            for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                prop_assert!(a.quantile(q) == whole.quantile(q),
                             "q{q}: {} != {}", a.quantile(q),
                             whole.quantile(q));
            }
            Ok(())
        });
    }

    /// Quantiles of a merged histogram stay monotone in q.
    #[test]
    fn prop_merged_quantiles_monotone() {
        forall("merged quantiles monotone", 200, |g| {
            let mut a = Histogram::new();
            let mut b = Histogram::new();
            for v in sample(g) {
                a.record(v);
            }
            for v in sample(g) {
                b.record(v);
            }
            a.merge(&b);
            let qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0];
            for w in qs.windows(2) {
                let (lo, hi) = (a.quantile(w[0]), a.quantile(w[1]));
                prop_assert!(lo <= hi,
                             "q{} = {lo} > q{} = {hi}", w[0], w[1]);
            }
            Ok(())
        });
    }

    /// A value recorded exactly on a bucket edge lands in that edge's
    /// own (lower) bucket — `bucket` floors — so `fraction_le(edge)`
    /// counts it, and merging preserves the placement bit-for-bit.
    #[test]
    fn prop_bucket_edges_land_low() {
        forall("edge values land in the lower bucket", 200, |g| {
            let i = g.usize_in(1, NBUCKETS - 2);
            let edge = Histogram::edge(i);
            let b = Histogram::bucket(edge);
            prop_assert!(b <= i,
                         "edge({i}) = {edge} placed above its bucket \
                          ({b} > {i})");
            // floating-point log2 may land the edge one bucket early,
            // never late: the edge is the bucket's *lower* boundary
            prop_assert!(i - b <= 1, "edge({i}) fell to bucket {b}");
            let mut h = Histogram::new();
            h.record(edge);
            prop_assert!(h.fraction_le(edge) == 1.0,
                         "fraction_le(edge) = {} for bucket {i}",
                         h.fraction_le(edge));
            let mut m = Histogram::new();
            m.merge(&h);
            prop_assert!(m.counts == h.counts,
                         "merge moved the edge sample (bucket {i})");
            Ok(())
        });
    }
}
