#!/usr/bin/env sh
# Seed (or refresh) the golden summaries and stage them for commit.
#
# The golden-summary test self-seeds missing files and CI fails until
# they are committed; this script is the one-command way to pin them
# on a machine with a Rust toolchain.  The matrix includes the
# pipeline-parallel cells (4-device fleet, --pp-stages 2, sealed and
# coherent inter-stage links) — new cells are staged automatically:
#
#   tools/seed_goldens.sh           # seed missing goldens
#   UPDATE_GOLDENS=1 tools/seed_goldens.sh   # rewrite after an
#                                            # intentional change
set -e
cd "$(dirname "$0")/.."
cargo test --release --test golden_summary -- --nocapture
git add rust/tests/goldens
echo "--- staged goldens ---"
git status --short rust/tests/goldens
echo "commit rust/tests/goldens/ to pin them"
