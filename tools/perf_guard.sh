#!/usr/bin/env sh
# CI perf guard: time a serial lab smoke run, compute cells/second, and
# compare against the newest *recorded* BENCH_*.json trajectory point.
# The tolerance is deliberately loose — the run only fails when CI is
# more than 2x slower than the recorded serial figure — because CI
# boxes are noisy and the smoke grid is smaller than the paper-72 grid
# the baseline pins.  While every trajectory point is still
# `recorded: false` the guard is advisory: it prints and writes the
# bench table but cannot fail.
#
#   tools/perf_guard.sh [results-dir] [table-out.md]
#
# Expects `cargo build --release` to have run (uses target/release).
set -e
cd "$(dirname "$0")/.."
results="${1:-perf-guard-results}"
table="${2:-bench_table.md}"

start=$(date +%s%N)
./target/release/sincere lab run --preset smoke --synthetic-costs on \
    --threads 1 --results "$results"
end=$(date +%s%N)
wall=$(awk -v a="$start" -v b="$end" 'BEGIN { printf "%.3f", (b - a) / 1e9 }')

cells=$(python3 -c 'import json, sys; print(len(json.load(open(sys.argv[1]))))' \
        "$results/sweep_cells.json")
cps=$(awk -v c="$cells" -v w="$wall" \
      'BEGIN { printf "%.2f", c / (w > 0 ? w : 1e-9) }')

# newest trajectory point with recorded=true and a non-null serial
# cells/s figure; "none" when the whole trajectory is
# documented-unrecorded.  A BENCH file that exists but does not parse
# is a hard error — silently skipping it would quietly un-pin the
# baseline the guard exists to enforce.
baseline=$(python3 - <<'EOF'
import glob, json, re, sys
best = None
for p in glob.glob("BENCH_*.json"):
    m = re.match(r"BENCH_(\d+)\.json$", p)
    if not m:
        continue
    try:
        d = json.load(open(p))
    except ValueError as e:
        print("perf-guard: malformed %s: %s" % (p, e), file=sys.stderr)
        sys.exit(1)
    serial = (d.get("bench", {}).get("lab_grid") or {}).get("cells_per_s_serial")
    if d.get("recorded") and isinstance(serial, (int, float)):
        if best is None or int(m.group(1)) > best[0]:
            best = (int(m.group(1)), serial)
print("%d %s" % best if best else "none")
EOF
)

{
    echo "# Perf guard — lab smoke preset, serial run"
    echo
    echo "| preset | cells | wall (s) | cells/s | baseline cells/s | verdict |"
    echo "|---|---|---|---|---|---|"
} > "$table"

if [ "$baseline" = "none" ]; then
    echo "| smoke | $cells | $wall | $cps | unrecorded | advisory |" >> "$table"
    cat "$table"
    echo "perf-guard: no recorded BENCH_*.json baseline yet — advisory only"
    exit 0
fi

point=$(printf '%s' "$baseline" | cut -d' ' -f1)
ref=$(printf '%s' "$baseline" | cut -d' ' -f2)
verdict=$(awk -v got="$cps" -v ref="$ref" \
          'BEGIN { print (got * 2 >= ref) ? "ok" : "regression" }')
echo "| smoke | $cells | $wall | $cps | ${ref} (BENCH_${point}) | $verdict |" >> "$table"
cat "$table"
if [ "$verdict" = "regression" ]; then
    echo "perf-guard: ${cps} cells/s is more than 2x below the" \
         "BENCH_${point} serial figure (${ref})"
    exit 1
fi
