#!/usr/bin/env sh
# Record one point of the perf trajectory (ROADMAP item: tracked
# simulator speed): run the lab_grid and hotpath benches and assemble
# BENCH_<n>.json at the repo root with the two headline figures —
# cells/sec (grid throughput of the lab runner) and simulated
# requests/sec (DES request volume per wall second).
#
#   tools/record_bench.sh 6        # writes BENCH_6.json
#
# Requires a Rust toolchain and `make artifacts` (tools/gen_artifacts.py)
# to have been run; the container CI image has neither, so trajectory
# points are recorded on developer machines and committed.
set -e
n="${1:?usage: tools/record_bench.sh <trajectory-number>}"
cd "$(dirname "$0")/.."
out="BENCH_${n}.json"

cargo build --release --benches

grid=$(./target/release/deps/lab_grid-* 2>/dev/null \
       || cargo bench --bench lab_grid 2>/dev/null)
hot=$(cargo bench --bench hotpath 2>/dev/null)

# lab_grid rows: | threads | wall (s) | cells/s | sim req/s | speedup |
# take the best (max cells/s) row as the headline figure
best=$(printf '%s\n' "$grid" | awk -F'|' '
    /^\| [0-9]+ \|/ {
        gsub(/ /, "", $4); gsub(/ /, "", $5)
        if ($4 + 0 > c) { c = $4 + 0; r = $5 + 0; t = $2 + 0 }
    }
    END { printf "%s %s %s", c, r, t }')
cells_s=$(printf '%s' "$best" | cut -d' ' -f1)
reqs_s=$(printf '%s' "$best" | cut -d' ' -f2)
threads=$(printf '%s' "$best" | cut -d' ' -f3)

serial=$(printf '%s\n' "$grid" | awk -F'|' '
    /^\| 1 \|/ { gsub(/ /, "", $4); print $4 + 0; exit }')

# hotpath headline: the slowest strategy decide mean, in microseconds
decide=$(printf '%s\n' "$hot" | awk -F'|' '
    /decide\// { gsub(/[^0-9.]/, "", $3); if ($3 + 0 > d) d = $3 + 0 }
    END { print d }')

host=$(uname -sm | tr ' ' '-')
date=$(date -u +%Y-%m-%d)

cat > "$out" <<EOF
{
  "trajectory_point": ${n},
  "date": "${date}",
  "host": "${host}",
  "bench": {
    "lab_grid": {
      "preset": "paper-72",
      "cells_per_s_best": ${cells_s:-0},
      "cells_per_s_serial": ${serial:-0},
      "sim_requests_per_s_best": ${reqs_s:-0},
      "best_threads": ${threads:-0}
    },
    "hotpath": {
      "decide_mean_us_worst": ${decide:-0}
    }
  },
  "notes": "recorded by tools/record_bench.sh; compare against the previous BENCH_*.json before merging a perf-sensitive change"
}
EOF
echo "wrote ${out}:"
cat "$out"
