#!/usr/bin/env sh
# Record one point of the perf trajectory (ROADMAP item: tracked
# simulator speed): run the lab_grid and hotpath benches and assemble
# BENCH_<n>.json at the repo root with the headline figures —
# cells/sec (grid throughput of the lab runner) and simulated
# requests/sec (DES request volume per wall second) for each bench
# preset, plus the worst-case strategy-decide mean.
#
#   tools/record_bench.sh 7        # writes BENCH_7.json
#
# Requires a Rust toolchain and `make artifacts` (tools/gen_artifacts.py)
# to have been run; the container CI image has neither, so trajectory
# points are recorded on developer machines and committed.
set -e
n="${1:?usage: tools/record_bench.sh <trajectory-number>}"
cd "$(dirname "$0")/.."
out="BENCH_${n}.json"

cargo build --release --benches

grid=$(./target/release/deps/lab_grid-* 2>/dev/null \
       || cargo bench --bench lab_grid 2>/dev/null)
hot=$(cargo bench --bench hotpath 2>/dev/null)

# lab_grid prints one section per preset:
#   # Lab grid scaling [<preset>] — ...
#   | threads | wall (s) | cells/s | sim req/s | speedup vs 1 |
# Extract "<best cells/s> <its req/s> <its threads> <serial cells/s>"
# for one preset's section.
preset_stats() {
    printf '%s\n' "$grid" | awk -F'|' -v preset="$1" '
        /^# Lab grid scaling/ { in_sec = index($0, "[" preset "]") > 0 }
        in_sec && /^\| [0-9]+ \|/ {
            gsub(/ /, "", $2); gsub(/ /, "", $4); gsub(/ /, "", $5)
            if ($2 + 0 == 1) s = $4 + 0
            if ($4 + 0 > c) { c = $4 + 0; r = $5 + 0; t = $2 + 0 }
        }
        END { printf "%s %s %s %s", c, r, t, s }'
}

p72=$(preset_stats "paper-72")
ten=$(preset_stats "tenancy")
p72_cells=$(printf '%s' "$p72" | cut -d' ' -f1)
p72_reqs=$(printf '%s' "$p72" | cut -d' ' -f2)
p72_threads=$(printf '%s' "$p72" | cut -d' ' -f3)
p72_serial=$(printf '%s' "$p72" | cut -d' ' -f4)
ten_cells=$(printf '%s' "$ten" | cut -d' ' -f1)
ten_reqs=$(printf '%s' "$ten" | cut -d' ' -f2)
ten_threads=$(printf '%s' "$ten" | cut -d' ' -f3)
ten_serial=$(printf '%s' "$ten" | cut -d' ' -f4)

# hotpath headline: the slowest strategy decide mean, in microseconds
decide=$(printf '%s\n' "$hot" | awk -F'|' '
    /decide\// { gsub(/[^0-9.]/, "", $3); if ($3 + 0 > d) d = $3 + 0 }
    END { print d }')

host=$(uname -sm | tr ' ' '-')
date=$(date -u +%Y-%m-%d)

cat > "$out" <<EOF
{
  "trajectory_point": ${n},
  "date": "${date}",
  "host": "${host}",
  "recorded": true,
  "bench": {
    "lab_grid": {
      "preset": "paper-72",
      "cells_per_s_best": ${p72_cells:-0},
      "cells_per_s_serial": ${p72_serial:-0},
      "sim_requests_per_s_best": ${p72_reqs:-0},
      "best_threads": ${p72_threads:-0}
    },
    "lab_grid_tenancy": {
      "preset": "tenancy",
      "cells_per_s_best": ${ten_cells:-0},
      "cells_per_s_serial": ${ten_serial:-0},
      "sim_requests_per_s_best": ${ten_reqs:-0},
      "best_threads": ${ten_threads:-0}
    },
    "hotpath": {
      "decide_mean_us_worst": ${decide:-0}
    }
  },
  "notes": "recorded by tools/record_bench.sh; compare against the previous BENCH_*.json before merging a perf-sensitive change"
}
EOF
echo "wrote ${out}:"
cat "$out"
