#!/usr/bin/env python3
"""Generate the AOT artifact set consumed by the Rust runtime.

Stands in for `python/compile/aot.py` + JAX lowering in environments
without an XLA toolchain: emits, for each simulated model family,

* a deterministic float32 weight blob (`<family>.bin`),
* one HLO-text artifact per batch size (`<family>_b<N>.hlo.txt`) whose
  `// sincere.meta:` header carries the shapes and calibrated work
  factors the offline PJRT stand-in (rust/vendor/xla) executes, and
* `manifest.json` binding it all together (format_version 1 — the
  contract parsed by `rust/src/runtime/manifest.rs`).

Sizes are chosen so the device model reproduces the paper's memory
regime on the 24 MB simulated HBM: every family fits its largest batch
workspace except granite-sim, which OOMs at batch 32 (§III-D2).

Usage: python3 tools/gen_artifacts.py [--out rust/artifacts]
"""

import argparse
import hashlib
import json
import os
import struct

BATCH_SIZES = [1, 2, 4, 8, 16, 32]

FAMILIES = [
    # name, hf_name, paper_gb, d_model, n_layers, n_heads, d_ff, act
    ("llama-sim", "meta-llama/Llama-2-7b-chat", 13.48, 96, 6, 6, 384,
     "silu"),
    ("gemma-sim", "google/gemma-7b-it", 17.05, 128, 7, 8, 512, "gelu"),
    ("granite-sim", "ibm-granite/granite-13b-chat", 26.02, 160, 8, 10,
     640, "silu"),
]

VOCAB = 512
PROMPT_LEN = 16
DECODE_LEN = 50
CACHE_LEN = 64


def param_layout(d_model, n_layers, d_ff):
    """(name, shape) list matching the synthetic decoder-only stack."""
    params = [("embed", [VOCAB, d_model])]
    for layer in range(n_layers):
        params += [
            (f"l{layer}.attn_qkv", [d_model, 3 * d_model]),
            (f"l{layer}.attn_out", [d_model, d_model]),
            (f"l{layer}.mlp_in", [d_model, d_ff]),
            (f"l{layer}.mlp_out", [d_ff, d_model]),
            (f"l{layer}.ln1", [d_model]),
            (f"l{layer}.ln2", [d_model]),
        ]
    params += [("final_ln", [d_model]), ("lm_head", [d_model, VOCAB])]
    return params


def gen_weights(seed, numel):
    """Deterministic float32 stream in [-0.5, 0.5) (xorshift-based)."""
    out = bytearray()
    state = (seed * 0x9E3779B97F4A7C15 + 1) & 0xFFFFFFFFFFFFFFFF
    for _ in range(numel):
        state ^= (state << 13) & 0xFFFFFFFFFFFFFFFF
        state ^= state >> 7
        state ^= (state << 17) & 0xFFFFFFFFFFFFFFFF
        out += struct.pack("<f", (state >> 11) / float(1 << 53) - 0.5)
    return bytes(out)


HLO_HEADER = """HloModule {name}_b{batch}, \
entry_computation_layout={{(s32[{batch},{prompt}]{{1,0}}, \
f32[{vocab},{d_model}]{{1,0}}, /*...weights...*/)->\
(s32[{batch},{decode}]{{1,0}})}}

// sincere.meta: name={name} batch={batch} prompt_len={prompt} \
decode_len={decode} vocab={vocab} d_model={d_model} \
n_layers={n_layers} work_base={work_base} work_per_row={work_per_row}
//
// AOT-lowered decoder-only transformer, {n_layers} layers, batch \
{batch}.
// Lowered by tools/gen_artifacts.py (offline stand-in for
// python/compile/aot.py + jax.jit lowering). The text below mirrors
// the structure of the real HLO dump; the offline PJRT stand-in
// executes the sincere.meta contract above.
"""


def hlo_body(name, batch, d_model, n_layers, d_ff):
    """Plausible HLO-ish text, padded past 10 KB like a real dump."""
    lines = []
    lines.append(f"%fused_rmsnorm.{name} (x: f32[{batch},{d_model}]) -> "
                 f"f32[{batch},{d_model}] {{")
    lines.append(f"  %x = f32[{batch},{d_model}]{{1,0}} parameter(0)")
    lines.append(f"  %sq = f32[{batch},{d_model}]{{1,0}} multiply(%x, %x)")
    lines.append(f"  %mean = f32[{batch}]{{0}} reduce(%sq), "
                 f"dimensions={{1}}, to_apply=%add")
    lines.append("  ROOT %norm = divide(%x, %rsqrt)")
    lines.append("}")
    lines.append("")
    for layer in range(n_layers):
        for op, shape in [
            ("qkv_dot", f"f32[{batch},{3 * d_model}]"),
            ("attn_scores", f"f32[{batch},{PROMPT_LEN},{PROMPT_LEN}]"),
            ("attn_softmax", f"f32[{batch},{PROMPT_LEN},{PROMPT_LEN}]"),
            ("attn_out_dot", f"f32[{batch},{d_model}]"),
            ("mlp_in_dot", f"f32[{batch},{d_ff}]"),
            ("mlp_act", f"f32[{batch},{d_ff}]"),
            ("mlp_out_dot", f"f32[{batch},{d_model}]"),
            ("residual_add", f"f32[{batch},{d_model}]"),
        ]:
            lines.append(
                f"  %l{layer}.{op} = {shape}{{1,0}} "
                f"custom-call(%l{layer}.in), "
                f"custom_call_target=\"__pallas${op}\", "
                f"backend_config={{\"layer\":{layer}}}")
    lines.append(f"  %logits = f32[{batch},{VOCAB}]{{1,0}} "
                 f"dot(%final_norm, %lm_head)")
    lines.append(f"  ROOT %decode = s32[{batch},{DECODE_LEN}]{{1,0}} "
                 f"custom-call(%logits), "
                 f"custom_call_target=\"__pallas$greedy_decode\"")
    body = "\n".join(lines)
    pad_line = ("// pad: xla lowering metadata "
                + "-" * 40)
    while len(body) < 11_000:
        body += "\n" + pad_line
    return body + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="rust/artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    families_json = []
    for fi, (name, hf, paper_gb, d_model, n_layers, n_heads, d_ff,
             act) in enumerate(FAMILIES):
        layout = param_layout(d_model, n_layers, d_ff)
        params_json, offset = [], 0
        for pname, shape in layout:
            numel = 1
            for d in shape:
                numel *= d
            size = 4 * numel
            params_json.append({
                "name": pname,
                "shape": shape,
                "offset_bytes": offset,
                "size_bytes": size,
            })
            offset += size
        total_numel = offset // 4
        blob = gen_weights(0xC0FFEE + 7919 * fi, total_numel)
        assert len(blob) == offset
        blob_file = f"{name}.bin"
        with open(os.path.join(args.out, blob_file), "wb") as f:
            f.write(blob)

        work_base = 250 * n_layers * d_model
        work_per_row = work_base // 12
        artifacts = {}
        for batch in BATCH_SIZES:
            art = f"{name}_b{batch}.hlo.txt"
            artifacts[str(batch)] = art
            text = HLO_HEADER.format(
                name=name, batch=batch, prompt=PROMPT_LEN,
                decode=DECODE_LEN, vocab=VOCAB, d_model=d_model,
                n_layers=n_layers, work_base=work_base,
                work_per_row=work_per_row)
            text += hlo_body(name, batch, d_model, n_layers, d_ff)
            with open(os.path.join(args.out, art), "w") as f:
                f.write(text)

        kv_bytes_per_seq = 2 * n_layers * CACHE_LEN * d_model * 4
        families_json.append({
            "name": name,
            "hf_name": hf,
            "paper_gb": paper_gb,
            "d_model": d_model,
            "n_layers": n_layers,
            "n_heads": n_heads,
            "d_ff": d_ff,
            "vocab": VOCAB,
            "act": act,
            "prompt_len": PROMPT_LEN,
            "decode_len": DECODE_LEN,
            "cache_len": CACHE_LEN,
            "kv_bytes_per_seq": kv_bytes_per_seq,
            "param_count": total_numel,
            "weights": {
                "file": blob_file,
                "total_bytes": offset,
                "sha256": hashlib.sha256(blob).hexdigest(),
                "params": params_json,
            },
            "artifacts": artifacts,
        })
        print(f"{name}: {offset / 1e6:.2f} MB weights, "
              f"kv/seq {kv_bytes_per_seq / 1e3:.0f} KB, "
              f"{len(BATCH_SIZES)} artifacts")

    manifest = {
        "format_version": 1,
        "batch_sizes": BATCH_SIZES,
        "families": families_json,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
