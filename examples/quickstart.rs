//! Quickstart: load one model, run one batch, print the result.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the minimal API surface: manifest -> registry ->
//! (simulated, confidential) GPU -> swap manager -> execute.

use std::path::PathBuf;

use sincere::coordinator::swap::SwapManager;
use sincere::gpu::device::{GpuConfig, SimGpu};
use sincere::gpu::CcMode;
use sincere::runtime::{Manifest, ModelTable, Registry};
use sincere::workload::tokenizer::tokenize;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)?;

    // Compile just llama-sim at batch sizes 1 and 4 (fast startup).
    let registry = Registry::load(&manifest, &["llama-sim".to_string()],
                                  &[1, 4])?;
    println!("compiled llama-sim in {:.2}s",
             registry.total_compile_time.as_secs_f64());

    // Bring up a confidential GPU: attestation + encrypted DMA.
    let mut gpu = SimGpu::new(GpuConfig {
        mode: CcMode::On,
        ..GpuConfig::default()
    })?;
    // the swap manager records per-model stats through an intern table
    let mut swaps =
        SwapManager::new(ModelTable::shared(registry.names()));

    // Load the model through the CC bounce-buffer path.
    let rep = swaps.ensure_resident(&mut gpu, &registry, "llama-sim")?;
    println!("model load: {:.3}s ({:.3}s of AES-CTR+HMAC)",
             rep.load_s, rep.crypto_total_s);

    // Tokenize three prompts and run them as one batch.
    let spec = &registry.entry("llama-sim")?.spec;
    let prompts = [
        "Summarize the following invoice and flag anomalies",
        "Draft a reply to this support ticket about latency",
        "Explain the key risk factors in this filing excerpt",
    ];
    let rows: Vec<Vec<i32>> = prompts.iter()
        .map(|p| tokenize(p, spec.prompt_len, spec.vocab as u32))
        .collect();

    let exec = registry.execute("llama-sim", &rows)?;
    gpu.record_compute(exec.elapsed);
    println!("executed batch of {} (artifact b{}) in {:.3}s",
             rows.len(), exec.batch, exec.elapsed.as_secs_f64());
    for (i, toks) in exec.tokens.iter().enumerate() {
        println!("  request {i}: generated {} tokens, first 8: {:?}",
                 toks.len(), &toks[..8.min(toks.len())]);
    }

    println!("GPU util so far: {:.1}%  (mem in use: {:.2} MB)",
             gpu.utilization() * 100.0,
             gpu.mem_in_use() as f64 / 1e6);
    swaps.evict(&mut gpu);
    Ok(())
}
