//! End-to-end driver (DESIGN.md deliverable): serve the full 3-model
//! fleet against gamma traffic in CC mode with real PJRT execution,
//! exactly the paper's setting — one VM, one confidential GPU, model
//! swapping under relaxed-inference SLAs.
//!
//! ```bash
//! cargo run --release --example serve_multimodel [-- duration_s]
//! ```
//!
//! Writes request/batch/monitor CSVs + summary JSON to
//! `results/e2e/` and prints the summary.  Recorded in EXPERIMENTS.md.

use std::path::PathBuf;

use sincere::config::RunConfig;
use sincere::engine::EngineBuilder;
use sincere::runtime::{Manifest, Registry};
use sincere::sim::CostModel;

fn main() -> anyhow::Result<()> {
    let duration_s: f64 = std::env::args().nth(1)
        .map(|s| s.parse().expect("duration seconds"))
        .unwrap_or(60.0);

    let mut cfg = RunConfig {
        duration_s,
        drain_s: 18.0,
        mean_rps: 9.0,
        sla_s: 18.0,
        pattern: "gamma".into(),
        strategy: "select-batch+timer".into(),
        results_dir: Some(PathBuf::from("results/e2e")),
        label: "e2e_multimodel_cc".into(),
        ..RunConfig::default()
    };
    cfg.set("mode", "cc")?;

    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    eprintln!("[e2e] compiling all (family, batch) executables ...");
    let mut registry = Registry::load(&manifest, &[], &[])?;
    eprintln!("[e2e] compiled in {:.1}s",
              registry.total_compile_time.as_secs_f64());

    // Profile OBS quickly (1 rep) so strategies see real values; reuse
    // a cached cost model when present.
    let cm_path = PathBuf::from("results/cost_model.json");
    let cm = if cm_path.exists() {
        CostModel::load(&cm_path)?
    } else {
        eprintln!("[e2e] profiling OBS (one-time) ...");
        let cm = CostModel::measure(&registry, &cfg.gpu, 1)?;
        cm.save(&cm_path)?;
        cm
    };
    for name in registry.names() {
        if let Ok(mc) = cm.costs(&name) {
            registry.set_obs(&name, mc.obs)?;
        }
    }

    eprintln!("[e2e] serving {} for {:.0}s (CC mode, gamma 9 rps, \
               SLA 18s) ...", registry.names().join(", "), duration_s);
    let (summary, recorder) = EngineBuilder::new(&cfg)
        .real(&registry)?.run()?;

    println!("\n=== end-to-end summary ===");
    println!("{}", summary.brief());
    println!("\nper-model load samples (Fig 3 shape):");
    // batches carry interned ids; resolve through the registry's
    // sorted intern table (the same table the backend built)
    let table = sincere::runtime::ModelTable::new(registry.names());
    let mut agg: std::collections::BTreeMap<String, (f64, usize)> =
        Default::default();
    for b in &recorder.batches {
        if b.swapped {
            let e = agg.entry(table.name(b.model).to_string())
                .or_default();
            e.0 += b.load_s;
            e.1 += 1;
        }
    }
    for (model, (total, n)) in agg {
        println!("  {model}: mean load {:.3}s over {n} swaps",
                 total / n as f64);
    }
    println!("\nCSVs + summary JSON in results/e2e/");
    anyhow::ensure!(summary.completed > 0, "nothing completed");
    Ok(())
}
