//! Network serving demo: the paper's Flask-API architecture end to end.
//!
//! Starts the HTTP front-end on a local port, fires a gamma-distributed
//! open-loop load from client threads (the paper's request-generation
//! script), and prints per-request and aggregate results.
//!
//! ```bash
//! cargo run --release --example http_serving [-- duration_s]
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use sincere::config::RunConfig;
use sincere::coordinator::http::{http_call, run_http};
use sincere::runtime::{Manifest, Registry};
use sincere::traffic::rng::Pcg64;
use sincere::traffic::pattern_by_name;
use sincere::util::json::Json;
use sincere::workload::promptgen::PromptGen;

fn main() -> anyhow::Result<()> {
    let duration_s: f64 = std::env::args().nth(1)
        .map(|s| s.parse().expect("duration seconds")).unwrap_or(20.0);

    let manifest = Manifest::load(&std::path::PathBuf::from("artifacts"))?;
    eprintln!("[http] compiling executables ...");
    let registry = Registry::load(
        &manifest,
        &["llama-sim".to_string(), "gemma-sim".to_string()],
        &[1, 2, 4, 8])?;

    let mut cfg = RunConfig {
        sla_s: 18.0,
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    };
    cfg.set("mode", "cc")?;

    let shutdown = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();

    // ---- client side: open-loop gamma load over real sockets ----------
    let client_shutdown = shutdown.clone();
    let clients = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        eprintln!("[http] serving on {addr}");
        let models = vec!["llama-sim".to_string(), "gemma-sim".to_string()];
        let mut rng = Pcg64::new(7);
        let pattern = pattern_by_name("gamma").unwrap();
        let schedule = pattern.generate(duration_s, 4.0, &models, &mut rng);
        let mut prompts = PromptGen::new(11, 24);
        let t0 = std::time::Instant::now();
        let lat = Arc::new(Mutex::new(Vec::<f64>::new()));
        let mut workers = Vec::new();
        let (mut ok, mut expired) = (0u64, 0u64);
        for a in &schedule {
            let wait = Duration::from_secs_f64(a.at_s);
            if wait > t0.elapsed() {
                std::thread::sleep(wait - t0.elapsed());
            }
            let body = Json::obj(vec![
                ("model", Json::str(a.model.clone())),
                ("prompt", Json::str(prompts.next_prompt(&a.model))),
            ]).to_string();
            let lat = lat.clone();
            workers.push(std::thread::spawn(move || {
                match http_call(&addr, "POST", "/infer", Some(&body)) {
                    Ok((200, resp)) => {
                        let j = Json::parse(&resp).unwrap();
                        lat.lock().unwrap().push(
                            j.req("latency_s").unwrap().as_f64().unwrap());
                        (1u64, 0u64)
                    }
                    Ok((408, _)) => (0, 1),
                    other => {
                        eprintln!("[http] unexpected: {other:?}");
                        (0, 0)
                    }
                }
            }));
        }
        for w in workers {
            let (o, e) = w.join().unwrap();
            ok += o;
            expired += e;
        }
        let lat = lat.lock().unwrap();
        println!("\n=== http load summary ===");
        println!("sent {} | served {} | expired {}", schedule.len(), ok,
                 expired);
        println!("latency mean {:.2}s p-max {:.2}s",
                 sincere::util::mean(&lat),
                 lat.iter().cloned().fold(0.0, f64::max));
        let (code, stats) = http_call(&addr, "GET", "/stats", None)
            .unwrap();
        println!("server stats ({code}): {stats}");
        client_shutdown.store(true, Ordering::Relaxed);
    });

    let stats = run_http(&cfg, &registry, "127.0.0.1:0", shutdown,
                         move |addr| {
                             addr_tx.send(addr).unwrap();
                         })?;
    clients.join().unwrap();
    println!("scheduler: completed={} expired={}",
             stats.completed.load(Ordering::Relaxed),
             stats.expired.load(Ordering::Relaxed));
    Ok(())
}
