//! The headline comparison: the *same* workload served in CC and No-CC
//! mode, real execution, identical seeds — the paper's central
//! experiment in miniature.
//!
//! ```bash
//! cargo run --release --example cc_vs_nocc [-- duration_s]
//! ```

use std::path::PathBuf;

use sincere::config::RunConfig;
use sincere::engine::EngineBuilder;
use sincere::metrics::report;
use sincere::runtime::{Manifest, Registry};

fn main() -> anyhow::Result<()> {
    let duration_s: f64 = std::env::args().nth(1)
        .map(|s| s.parse().expect("duration seconds"))
        .unwrap_or(45.0);

    let manifest = Manifest::load(&PathBuf::from("artifacts"))?;
    eprintln!("[cc-vs-nocc] compiling executables ...");
    let registry = Registry::load(&manifest, &[], &[])?;

    let mut cells = Vec::new();
    for mode in ["no-cc", "cc"] {
        let mut cfg = RunConfig {
            duration_s,
            drain_s: 8.0,
            mean_rps: 9.0,
            sla_s: 12.0, // the paper's most discriminating SLA (40 s x 0.3)
            pattern: "gamma".into(),
            strategy: "select-batch+timer".into(),
            results_dir: Some(PathBuf::from("results/cc_vs_nocc")),
            ..RunConfig::default()
        };
        cfg.set("mode", mode)?;
        cfg.label = cfg.cell_label();
        eprintln!("[cc-vs-nocc] running {mode} ...");
        let (summary, _) = EngineBuilder::new(&cfg).real(&registry)?
            .run()?;
        println!("{}", summary.brief());
        cells.push(summary);
    }

    println!("\n{}", report::cells_table(&cells));
    let h = report::headline_ratios(&cells);
    println!("{}", report::headline_table(&h));

    // the paper's direction must hold: CC slower, lower util
    anyhow::ensure!(h.latency_delta_frac < 0.0,
                    "expected No-CC latency below CC");
    anyhow::ensure!(h.util_gain_frac > 0.0,
                    "expected No-CC GPU utilization above CC");
    Ok(())
}
