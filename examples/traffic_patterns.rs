//! Fig 2 reproduction: the three input traffic distributions at the
//! same mean rate, rendered as ASCII rate histograms over time.
//!
//! ```bash
//! cargo run --release --example traffic_patterns
//! ```

use sincere::traffic::rng::Pcg64;
use sincere::traffic::{pattern_by_name, PATTERN_NAMES};

fn main() -> anyhow::Result<()> {
    let duration = 120.0;
    let mean_rps = 4.0;
    let models = vec!["llama-sim".to_string(), "gemma-sim".to_string(),
                      "granite-sim".to_string()];
    let bins = 30usize;
    let bin_w = duration / bins as f64;

    println!("traffic patterns at mean {mean_rps} req/s over \
              {duration:.0}s (Fig 2)\n");
    for name in PATTERN_NAMES {
        let mut rng = Pcg64::new(2024);
        let pattern = pattern_by_name(name)?;
        let arrivals = pattern.generate(duration, mean_rps, &models,
                                        &mut rng);
        let mut counts = vec![0usize; bins];
        for a in &arrivals {
            counts[((a.at_s / bin_w) as usize).min(bins - 1)] += 1;
        }
        let realized = arrivals.len() as f64 / duration;
        println!("-- {name}: {} arrivals, realized mean {realized:.2} rps",
                 arrivals.len());
        let peak = *counts.iter().max().unwrap() as f64;
        for (i, &c) in counts.iter().enumerate() {
            let bar = "#".repeat((c as f64 / peak * 50.0).round() as usize);
            println!("  {:>5.0}s |{bar:<50}| {:.1} rps",
                     i as f64 * bin_w, c as f64 / bin_w);
        }
        println!();
    }
    Ok(())
}
