//! Full paper reproduction driver: regenerates every table and figure.
//!
//! ```bash
//! cargo run --release --example reproduce_paper [-- real_cell_secs]
//! ```
//!
//! Pipeline (mirrors §III-A):
//!  1. profile model load/unload per mode        -> Fig 3 table
//!  2. profile throughput vs batch size + OBS    -> Fig 4 table
//!  3. full 72-cell grid via calibrated DES      -> Fig 5/6/7 tables
//!  4. real-execution validation cells           -> §Calibration
//!  5. headline ratios vs the paper's abstract   -> summary table
//!
//! Everything is written to `results/paper/REPORT.md` plus JSON; the
//! numbers quoted in EXPERIMENTS.md come from this driver.

use std::fmt::Write as _;
use std::path::PathBuf;

use sincere::config::{RunConfig, SLA_LADDER};
use sincere::coordinator::strategy_names;
use sincere::engine::{EngineBuilder, RunSummary};
use sincere::gpu::CcMode;
use sincere::metrics::report;
use sincere::runtime::{Manifest, Registry};
use sincere::sim::CostModel;
use sincere::traffic::PATTERN_NAMES;
use sincere::util::json::Json;

fn main() -> anyhow::Result<()> {
    let real_cell_secs: f64 = std::env::args().nth(1)
        .map(|s| s.parse().expect("seconds")).unwrap_or(45.0);
    let out_dir = PathBuf::from("results/paper");
    std::fs::create_dir_all(&out_dir)?;
    let mut md = String::new();
    writeln!(md, "# Reproduction report — Performance of Confidential \
                  Computing GPUs\n")?;
    writeln!(md, "Time scale: 0.3× the paper (60 s runs, SLAs 12/18/24 s \
                  instead of 40/60/80 s; see DESIGN.md §Substitutions).\n")?;

    // ---------------- 1+2: profiling --------------------------------
    let manifest = Manifest::load(&PathBuf::from("artifacts"))?;
    eprintln!("[paper] compiling all executables ...");
    let mut registry = Registry::load(&manifest, &[], &[])?;
    eprintln!("[paper] compiled in {:.1}s",
              registry.total_compile_time.as_secs_f64());

    let base_cfg = RunConfig::default();
    let cm_path = PathBuf::from("results/cost_model.json");
    let cm = if cm_path.exists() {
        eprintln!("[paper] using cached cost model {cm_path:?}");
        CostModel::load(&cm_path)?
    } else {
        eprintln!("[paper] profiling (Fig 3 + Fig 4) ...");
        let cm = CostModel::measure(&registry, &base_cfg.gpu, 3)?;
        cm.save(&cm_path)?;
        cm
    };
    for name in registry.names() {
        registry.set_obs(&name, cm.costs(&name)?.obs)?;
    }

    writeln!(md, "## Table II — model fleet\n")?;
    writeln!(md, "| model | stands in for | paper size | sim weights |")?;
    writeln!(md, "|---|---|---|---|")?;
    for f in &manifest.families {
        writeln!(md, "| {} | {} | {:.2} GB | {:.2} MB |", f.name,
                 f.hf_name, f.paper_gb, f.weight_bytes() as f64 / 1e6)?;
    }

    writeln!(md, "\n## Fig 3 — model load times (CC vs No-CC)\n")?;
    writeln!(md, "| model | No-CC load (s) | CC load (s) | CC/No-CC | \
                  unload (s) |")?;
    writeln!(md, "|---|---|---|---|---|")?;
    for (name, mc) in &cm.models {
        writeln!(md, "| {} | {:.3} | {:.3} | {:.2}× | {:.4} |", name,
                 mc.load_s_plain, mc.load_s_cc,
                 mc.load_s_cc / mc.load_s_plain.max(1e-9), mc.unload_s)?;
    }
    writeln!(md, "\nPaper shape: CC load significantly higher; unloads \
                  milliseconds in both modes.\n")?;

    writeln!(md, "## Fig 4 — inference throughput vs batch size\n")?;
    writeln!(md, "| model | batch | exec (s) | throughput (req/s) | |")?;
    writeln!(md, "|---|---|---|---|---|")?;
    for (name, mc) in &cm.models {
        for (&b, &e) in &mc.exec_s_by_batch {
            writeln!(md, "| {} | {} | {:.3} | {:.2} | {} |", name, b, e,
                     b as f64 / e,
                     if b == mc.obs { "**OBS**" } else { "" })?;
        }
        for &b in &mc.oom_batches {
            writeln!(md, "| {} | {} | — | — | OOM |", name, b)?;
        }
    }

    // ---------------- 3: the 72-cell DES grid -----------------------
    eprintln!("[paper] running the 72-cell grid (DES) ...");
    let mut cells: Vec<RunSummary> = Vec::new();
    for mode in [CcMode::Off, CcMode::On] {
        for pattern in PATTERN_NAMES {
            for strategy in strategy_names() {
                for &sla in SLA_LADDER {
                    let mut c = RunConfig::default();
                    c.mode = mode;
                    c.gpu.mode = mode;
                    c.pattern = pattern.to_string();
                    c.strategy = strategy.to_string();
                    c.sla_s = sla;
                    c.duration_s = 120.0;
                    c.drain_s = sla;
                    c.label = c.cell_label();
                    cells.push(EngineBuilder::new(&c).des(&manifest, &cm)?
                        .run()?.0);
                }
            }
        }
    }
    std::fs::write(out_dir.join("sweep_cells.json"),
                   Json::Arr(cells.iter().map(|c| c.to_json()).collect())
                       .to_string())?;

    writeln!(md, "\n## Fig 5 — latency and SLA attainment\n")?;
    writeln!(md, "Mean latency (s) / attainment %, by pattern and SLA, \
                  strategy = select-batch+timer:\n")?;
    writeln!(md, "| pattern | SLA | CC lat | No-CC lat | CC att % | \
                  No-CC att % |")?;
    writeln!(md, "|---|---|---|---|---|---|")?;
    for pattern in PATTERN_NAMES {
        for &sla in SLA_LADDER {
            let find = |mode: &str| cells.iter().find(|c| {
                c.mode == mode && &c.pattern == pattern
                    && c.sla_s == sla
                    && c.strategy == "select-batch+timer"
            }).unwrap();
            let cc = find("cc");
            let nc = find("no-cc");
            writeln!(md, "| {} | {} | {:.2} | {:.2} | {:.1} | {:.1} |",
                     pattern, sla, cc.latency_mean_s, nc.latency_mean_s,
                     cc.sla_attainment * 100.0,
                     nc.sla_attainment * 100.0)?;
        }
    }

    writeln!(md, "\n### §IV-A completion rates by SLA (all patterns, \
                  all strategies)\n")?;
    writeln!(md, "| SLA | paper CC | paper No-CC | measured CC | \
                  measured No-CC |")?;
    writeln!(md, "|---|---|---|---|---|")?;
    let paper_rates = [(SLA_LADDER[0], "50%", "70%"),
                       (SLA_LADDER[1], "70%", "85%"),
                       (SLA_LADDER[2], ">90%", ">90%")];
    for (sla, p_cc, p_nc) in paper_rates {
        let att = |mode: &str| 100.0 * report::mean_where(
            &cells, |c| c.mode == mode && c.sla_s == sla,
            |c| c.sla_attainment);
        writeln!(md, "| {} | {} | {} | {:.0}% | {:.0}% |", sla, p_cc,
                 p_nc, att("cc"), att("no-cc"))?;
    }

    writeln!(md, "\n## Fig 6 — throughput (SLA {})\n", SLA_LADDER[0])?;
    writeln!(md, "| pattern | strategy | CC thr (rps) | No-CC thr (rps) | \
                  gain % |")?;
    writeln!(md, "|---|---|---|---|---|")?;
    for pattern in PATTERN_NAMES {
        for strategy in strategy_names() {
            let find = |mode: &str| cells.iter().find(|c| {
                c.mode == mode && &c.pattern == pattern
                    && c.strategy == *strategy && c.sla_s == SLA_LADDER[0]
            }).unwrap();
            let cc = find("cc");
            let nc = find("no-cc");
            writeln!(md, "| {} | {} | {:.2} | {:.2} | {:+.0}% |", pattern,
                     strategy, cc.throughput_rps, nc.throughput_rps,
                     (nc.throughput_rps / cc.throughput_rps.max(1e-9)
                      - 1.0) * 100.0)?;
        }
    }

    writeln!(md, "\n## Fig 7 — GPU utilization\n")?;
    writeln!(md, "| pattern | CC util % | No-CC util % | gain % |")?;
    writeln!(md, "|---|---|---|---|")?;
    for pattern in PATTERN_NAMES {
        let util = |mode: &str| report::mean_where(
            &cells, |c| c.mode == mode && &c.pattern == pattern,
            |c| c.gpu_util);
        let (uc, un) = (util("cc"), util("no-cc"));
        writeln!(md, "| {} | {:.1} | {:.1} | {:+.0}% |", pattern,
                 uc * 100.0, un * 100.0, (un / uc.max(1e-9) - 1.0)
                 * 100.0)?;
    }

    writeln!(md, "\n## Headline comparison (abstract)\n")?;
    let h = report::headline_ratios(&cells);
    writeln!(md, "{}", report::headline_table(&h))?;

    // ---------------- 4: real-execution validation cells -------------
    eprintln!("[paper] real-execution validation cells \
               ({real_cell_secs:.0}s each) ...");
    writeln!(md, "\n## Calibration — DES vs real execution\n")?;
    writeln!(md, "gamma / select-batch+timer / SLA {} / {:.0}s:\n",
             SLA_LADDER[1], real_cell_secs)?;
    writeln!(md, "| mode | source | lat mean (s) | attain % | thr (rps) | \
                  GPU util % | swaps |")?;
    writeln!(md, "|---|---|---|---|---|---|---|")?;
    for mode in [CcMode::Off, CcMode::On] {
        let mut c = RunConfig::default();
        c.mode = mode;
        c.gpu.mode = mode;
        c.sla_s = SLA_LADDER[1];
        c.duration_s = real_cell_secs;
        c.drain_s = c.sla_s;
        c.results_dir = Some(out_dir.clone());
        c.label = format!("real_{}", c.cell_label());
        let (real, _) = EngineBuilder::new(&c).real(&registry)?.run()?;
        let mut cd = c.clone();
        cd.duration_s = real_cell_secs;
        // keep the real run's CSVs; the DES cell is summary-only
        cd.results_dir = None;
        let des = EngineBuilder::new(&cd).des(&manifest, &cm)?.run()?.0;
        for (src, s) in [("real", &real), ("DES", &des)] {
            writeln!(md, "| {} | {} | {:.2} | {:.1} | {:.2} | {:.1} | \
                          {} |", s.mode, src, s.latency_mean_s,
                     s.sla_attainment * 100.0, s.throughput_rps,
                     s.gpu_util * 100.0, s.swap_count)?;
        }
    }

    std::fs::write(out_dir.join("REPORT.md"), &md)?;
    println!("{md}");
    eprintln!("[paper] wrote results/paper/REPORT.md");
    Ok(())
}
