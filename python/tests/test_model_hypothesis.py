"""Architecture-randomized model invariants: generate() must equal the
independent reference oracle for arbitrary (tiny) transformer shapes,
not just the three shipped families."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.families import Family
from compile.model import make_generate_fn, reference_generate


@st.composite
def tiny_family(draw):
    n_heads = draw(st.sampled_from([1, 2, 4]))
    head_dim = draw(st.sampled_from([8, 16]))
    return Family(
        name=f"hyp-{draw(st.integers(0, 10**6))}",
        hf_name="hypothesis",
        paper_gb=0.0,
        d_model=n_heads * head_dim,
        n_layers=draw(st.integers(1, 2)),
        n_heads=n_heads,
        d_ff=draw(st.sampled_from([16, 48, 96])),
        vocab=draw(st.sampled_from([32, 64, 128])),
        act=draw(st.sampled_from(["silu", "gelu"])),
        prompt_len=draw(st.integers(2, 4)),
        decode_len=draw(st.integers(1, 4)),
        seed=draw(st.integers(0, 2**31 - 1)),
    )


@settings(max_examples=6, deadline=None)
@given(fam=tiny_family(), batch=st.integers(1, 3),
       prompt_seed=st.integers(0, 2**31 - 1))
def test_generate_matches_reference_for_random_architectures(
        fam, batch, prompt_seed):
    rng = np.random.RandomState(prompt_seed)
    prompt = rng.randint(0, fam.vocab, size=(batch, fam.prompt_len)) \
        .astype(np.int32)
    params = fam.init_params()
    args = [jnp.asarray(params[n]) for n, _ in fam.param_shapes()]
    got = np.asarray(jax.jit(make_generate_fn(fam))(
        jnp.asarray(prompt), *args)[0])
    want = reference_generate(fam, params, prompt)
    assert got.shape == (batch, fam.decode_len)
    assert np.array_equal(got, want), \
        f"{dataclasses.asdict(fam)}: {got} != {want}"
