"""Layer-2 model invariants: generate() vs an independent oracle,
determinism, teacher-forcing causality, and batch isolation."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.families import FAMILIES, by_name
from compile.model import PARAM_NAMES, make_generate_fn, reference_generate


def _tiny(fam, prompt_len=4, decode_len=5):
    return dataclasses.replace(fam, prompt_len=prompt_len,
                               decode_len=decode_len)


def _run(fam, prompt):
    params = fam.init_params()
    args = [jnp.asarray(params[n]) for n, _ in fam.param_shapes()]
    fn = jax.jit(make_generate_fn(fam))
    return np.asarray(fn(jnp.asarray(prompt), *args)[0])


@pytest.mark.parametrize("name", [f.name for f in FAMILIES])
def test_generate_matches_reference(name):
    fam = _tiny(by_name(name))
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, fam.vocab, size=(2, fam.prompt_len)) \
        .astype(np.int32)
    got = _run(fam, prompt)
    want = reference_generate(fam, fam.init_params(), prompt)
    assert got.shape == (2, fam.decode_len)
    assert np.array_equal(got, want)


def test_generate_deterministic():
    fam = _tiny(FAMILIES[0])
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, fam.vocab, size=(3, fam.prompt_len)) \
        .astype(np.int32)
    assert np.array_equal(_run(fam, prompt), _run(fam, prompt))


def test_batch_rows_are_independent():
    """Row i's generation must not depend on other rows in the batch —
    the batcher pads batches with dummy rows, so cross-row leakage would
    corrupt real requests."""
    fam = _tiny(FAMILIES[0])
    rng = np.random.RandomState(2)
    a = rng.randint(0, fam.vocab, size=(1, fam.prompt_len)).astype(np.int32)
    junk = rng.randint(0, fam.vocab, size=(3, fam.prompt_len)) \
        .astype(np.int32)
    solo = _run(fam, a)
    batched = _run(fam, np.concatenate([a, junk], axis=0))
    assert np.array_equal(solo[0], batched[0])


def test_identical_rows_generate_identically():
    fam = _tiny(FAMILIES[1])
    rng = np.random.RandomState(3)
    row = rng.randint(0, fam.vocab, size=(1, fam.prompt_len)) \
        .astype(np.int32)
    out = _run(fam, np.repeat(row, 4, axis=0))
    for i in range(1, 4):
        assert np.array_equal(out[0], out[i])


def test_prompt_changes_propagate():
    """Different prompts should (generically) give different generations —
    a guard against the graph ignoring its inputs."""
    fam = _tiny(FAMILIES[0], prompt_len=8, decode_len=8)
    rng = np.random.RandomState(4)
    p1 = rng.randint(0, fam.vocab, size=(1, fam.prompt_len)).astype(np.int32)
    p2 = (p1 + 123) % fam.vocab
    assert not np.array_equal(_run(fam, p1), _run(fam, p2))


def test_param_order_matches_param_names():
    for fam in FAMILIES:
        assert tuple(n for n, _ in fam.param_shapes()) == PARAM_NAMES


def test_family_table_ii_ordering():
    """Weight bytes must preserve the paper's Table II ordering:
    granite-7b (26.98 GB) > gemma-7b (17.07) > llama-3.1 (16.07)."""
    sizes = {f.name: f.weight_bytes() for f in FAMILIES}
    assert sizes["granite-sim"] > sizes["gemma-sim"] > sizes["llama-sim"]
    gbs = {f.name: f.paper_gb for f in FAMILIES}
    assert gbs["granite-sim"] > gbs["gemma-sim"] > gbs["llama-sim"]


def test_kv_bytes_per_seq():
    fam = FAMILIES[0]
    expect = 2 * 4 * fam.n_layers * fam.n_heads * fam.cache_len \
        * fam.head_dim
    assert fam.kv_bytes_per_seq() == expect


def test_init_params_deterministic_and_distinct():
    fam = FAMILIES[0]
    a, b = fam.init_params(), fam.init_params()
    for k in a:
        assert np.array_equal(a[k], b[k])
    other = FAMILIES[1].init_params()
    assert not np.array_equal(a["embed"][:, :64], other["embed"][:, :64])
