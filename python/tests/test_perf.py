"""Sanity tests over the §Perf analytic model."""

import os

import pytest

from compile import perf
from compile.families import FAMILIES


def test_all_kernels_fit_vmem():
    for fam in FAMILIES:
        for b in (1, 16, 32):
            for r in perf.family_step_matmuls(fam, b):
                assert r["vmem_ok"], (fam.name, b, r)


def test_flops_scale_with_batch_and_size():
    fam_small, fam_big = FAMILIES[0], FAMILIES[2]
    assert perf.family_flops(fam_small, 32) > perf.family_flops(fam_small, 1)
    assert perf.family_flops(fam_big, 16) > perf.family_flops(fam_small, 16)


def test_mxu_util_increases_with_batch():
    fam = FAMILIES[0]
    u1 = perf.family_step_matmuls(fam, 1)[0]["mxu_util"]
    u32 = perf.family_step_matmuls(fam, 32)[0]["mxu_util"]
    assert u32 > u1


def test_hlo_stats_on_real_artifact():
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "artifacts", "llama-sim_b16.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    st = perf.hlo_stats(open(path).read())
    assert st["total_instructions"] > 100
    assert st["while_loops"] >= 2, "decode must be scan-rolled"


def test_render_produces_markdown():
    text = perf.render(None)
    assert "MXU util" in text
    assert "| llama-sim |" in text
