"""Kernel-vs-reference correctness: the core L1 signal.

Every Pallas kernel is checked against its pure-jnp oracle in ref.py,
with hypothesis sweeping shapes (including non-block-multiple shapes that
exercise the padding path) and fixed-seed numpy data.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (attention_decode, fused_linear,
                             matmul_block_shapes, rmsnorm)
from compile.kernels import ref
from compile.kernels.fused_linear import MXU_DIM, vmem_bytes

RTOL, ATOL = 2e-5, 2e-5


def _arr(rng, *shape):
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


# ---------------------------------------------------------------- fused_linear

@pytest.mark.parametrize("act", ["none", "relu", "gelu", "silu"])
@pytest.mark.parametrize("m,k,n", [(1, 128, 128), (8, 128, 384),
                                   (32, 192, 576), (5, 96, 200),
                                   (130, 130, 130)])
def test_fused_linear_matches_ref(act, m, k, n):
    rng = np.random.RandomState(hash((act, m, k, n)) % 2**31)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    got = fused_linear(x, w, act=act)
    want = ref.fused_linear_ref(x, w, act=act)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("m,k,n", [(4, 64, 96), (17, 150, 33)])
def test_fused_linear_bias(m, k, n):
    rng = np.random.RandomState(7)
    x, w, b = _arr(rng, m, k), _arr(rng, k, n), _arr(rng, n)
    got = fused_linear(x, w, b, act="gelu")
    want = ref.fused_linear_ref(x, w, b, act="gelu")
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 140), k=st.integers(1, 140), n=st.integers(1, 140),
       act=st.sampled_from(["none", "relu", "gelu", "silu"]),
       bias=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_fused_linear_hypothesis(m, k, n, act, bias, seed):
    rng = np.random.RandomState(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    b = _arr(rng, n) if bias else None
    got = fused_linear(x, w, b, act=act)
    want = ref.fused_linear_ref(x, w, b, act=act)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fused_linear_shape_mismatch_raises():
    rng = np.random.RandomState(0)
    with pytest.raises(AssertionError):
        fused_linear(_arr(rng, 4, 8), _arr(rng, 9, 4))


def test_fused_linear_zero_input_gives_zero():
    out = fused_linear(jnp.zeros((3, 64)), jnp.zeros((64, 32)))
    assert np.all(np.asarray(out) == 0.0)


# ------------------------------------------------------------- block shapes

def test_block_shapes_small_dims_stay_whole():
    assert matmul_block_shapes(8, 96, 100) == (8, 96, 100)


def test_block_shapes_capped_at_mxu():
    bm, bk, bn = matmul_block_shapes(1000, 1000, 1000)
    assert (bm, bk, bn) == (MXU_DIM, MXU_DIM, MXU_DIM)


@given(m=st.integers(1, 4096), k=st.integers(1, 4096), n=st.integers(1, 4096))
@settings(max_examples=50, deadline=None)
def test_block_shapes_never_exceed_mxu_and_fit_vmem(m, k, n):
    bm, bk, bn = matmul_block_shapes(m, k, n)
    assert max(bm, bk, bn) <= MXU_DIM
    # one grid cell must fit comfortably in a 16 MiB VMEM budget
    assert vmem_bytes(bm, bk, bn) <= 16 * 1024 * 1024


# ------------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("m,d", [(1, 128), (8, 128), (33, 192), (200, 64)])
def test_rmsnorm_matches_ref(m, d):
    rng = np.random.RandomState(m * 1000 + d)
    x, w = _arr(rng, m, d), _arr(rng, d)
    np.testing.assert_allclose(rmsnorm(x, w), ref.rmsnorm_ref(x, w),
                               rtol=RTOL, atol=ATOL)


def test_rmsnorm_scale_invariant_direction():
    # rmsnorm(c*x) == rmsnorm(x) for any positive scalar c (eps-negligible)
    rng = np.random.RandomState(3)
    x, w = _arr(rng, 4, 128), _arr(rng, 128)
    a = rmsnorm(x, w)
    b = rmsnorm(x * 100.0, w)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 150), d=st.integers(2, 256),
       seed=st.integers(0, 2**31 - 1))
def test_rmsnorm_hypothesis(m, d, seed):
    rng = np.random.RandomState(seed)
    x, w = _arr(rng, m, d), _arr(rng, d)
    np.testing.assert_allclose(rmsnorm(x, w), ref.rmsnorm_ref(x, w),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- attention

@pytest.mark.parametrize("b,h,t,dh", [(1, 1, 4, 16), (3, 4, 10, 32),
                                      (8, 6, 66, 32)])
def test_attention_matches_ref(b, h, t, dh):
    rng = np.random.RandomState(b * 100 + t)
    q = _arr(rng, b, h, dh)
    k = _arr(rng, b, h, t, dh)
    v = _arr(rng, b, h, t, dh)
    for pos in [0, t // 2, t - 1]:
        got = attention_decode(q, k, v, jnp.int32(pos))
        want = ref.attention_decode_ref(q, k, v, pos)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_attention_masks_future_positions():
    """Garbage beyond pos must not leak into the output."""
    rng = np.random.RandomState(11)
    b, h, t, dh = 2, 2, 8, 16
    q = _arr(rng, b, h, dh)
    k = _arr(rng, b, h, t, dh)
    v = _arr(rng, b, h, t, dh)
    pos = 3
    k2 = k.at[:, :, pos + 1:, :].set(1e6)
    v2 = v.at[:, :, pos + 1:, :].set(-1e6)
    a = attention_decode(q, k, v, jnp.int32(pos))
    b_ = attention_decode(q, k2, v2, jnp.int32(pos))
    np.testing.assert_allclose(a, b_, rtol=1e-6, atol=1e-6)


def test_attention_pos0_returns_v0():
    """With only position 0 visible, softmax collapses to V[:, :, 0]."""
    rng = np.random.RandomState(12)
    b, h, t, dh = 2, 3, 5, 8
    q = _arr(rng, b, h, dh)
    k = _arr(rng, b, h, t, dh)
    v = _arr(rng, b, h, t, dh)
    out = attention_decode(q, k, v, jnp.int32(0))
    np.testing.assert_allclose(out, v[:, :, 0, :], rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 8), h=st.integers(1, 6), t=st.integers(1, 40),
       dh=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1),
       data=st.data())
def test_attention_hypothesis(b, h, t, dh, seed, data):
    pos = data.draw(st.integers(0, t - 1))
    rng = np.random.RandomState(seed)
    q = _arr(rng, b, h, dh)
    k = _arr(rng, b, h, t, dh)
    v = _arr(rng, b, h, t, dh)
    got = attention_decode(q, k, v, jnp.int32(pos))
    want = ref.attention_decode_ref(q, k, v, pos)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- dtypes

def test_fused_linear_bf16_inputs():
    """bf16 weights/activations with f32 accumulation (the MXU's native
    mode); result compared against the f32 reference at bf16 tolerance."""
    rng = np.random.RandomState(21)
    x32 = rng.randn(8, 64).astype(np.float32)
    w32 = rng.randn(64, 96).astype(np.float32)
    x = jnp.asarray(x32, dtype=jnp.bfloat16)
    w = jnp.asarray(w32, dtype=jnp.bfloat16)
    got = fused_linear(x, w, act="none")
    want = ref.fused_linear_ref(
        x.astype(jnp.float32), w.astype(jnp.float32), act="none")
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-1)


def test_rmsnorm_bf16_inputs():
    rng = np.random.RandomState(22)
    x = jnp.asarray(rng.randn(5, 64).astype(np.float32),
                    dtype=jnp.bfloat16)
    w = jnp.asarray(rng.randn(64).astype(np.float32), dtype=jnp.bfloat16)
    got = rmsnorm(x, w)
    want = ref.rmsnorm_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-1)
