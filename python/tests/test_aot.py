"""AOT pipeline tests: manifest schema, weight blob layout, HLO output."""

import dataclasses
import json
import os

import numpy as np
import pytest

from compile import aot
from compile.families import FAMILIES


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build a shrunken artifact set once for all tests in this module."""
    out = str(tmp_path_factory.mktemp("artifacts"))
    fam = dataclasses.replace(FAMILIES[0], prompt_len=4, decode_len=4)
    manifest = aot.build(out, [fam], (1, 2))
    return out, fam, manifest


def test_manifest_shape(built):
    _, fam, manifest = built
    assert manifest["format_version"] == 1
    assert manifest["batch_sizes"] == [1, 2]
    (entry,) = manifest["families"]
    assert entry["name"] == fam.name
    assert entry["hf_name"] == "Llama-3.1-8B"
    assert entry["paper_gb"] == pytest.approx(16.07)
    assert entry["cache_len"] == fam.prompt_len + fam.decode_len
    assert set(entry["artifacts"].keys()) == {"1", "2"}


def test_weight_blob_layout(built):
    out, fam, manifest = built
    entry = manifest["families"][0]["weights"]
    blob_path = os.path.join(out, entry["file"])
    blob = open(blob_path, "rb").read()
    assert len(blob) == entry["total_bytes"] == fam.weight_bytes()

    params = fam.init_params()
    for p in entry["params"]:
        raw = blob[p["offset_bytes"]:p["offset_bytes"] + p["size_bytes"]]
        arr = np.frombuffer(raw, np.float32).reshape(p["shape"])
        assert np.array_equal(arr, params[p["name"]]), p["name"]

    # offsets are dense and ordered
    offs = [p["offset_bytes"] for p in entry["params"]]
    sizes = [p["size_bytes"] for p in entry["params"]]
    assert offs[0] == 0
    for i in range(1, len(offs)):
        assert offs[i] == offs[i - 1] + sizes[i - 1]


def test_hlo_artifacts_written(built):
    out, _, manifest = built
    entry = manifest["families"][0]
    for b, fname in entry["artifacts"].items():
        text = open(os.path.join(out, fname)).read()
        assert text.startswith("HloModule"), fname
        # the prompt parameter must carry the right batch dimension
        assert f"s32[{b},4]" in text, fname


def test_weights_sha_matches(built):
    import hashlib
    out, _, manifest = built
    entry = manifest["families"][0]["weights"]
    blob = open(os.path.join(out, entry["file"]), "rb").read()
    assert hashlib.sha256(blob).hexdigest() == entry["sha256"]


def test_cli_roundtrip(tmp_path):
    rc = aot.main(["--out", str(tmp_path), "--families", "llama-sim",
                   "--batch-sizes", "1"])
    assert rc == 0
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["families"][0]["name"] == "llama-sim"
    assert (tmp_path / "llama-sim_b1.hlo.txt").exists()
    assert (tmp_path / "llama-sim.weights.bin").exists()
