"""Fused RMSNorm Pallas kernel: ``x * rsqrt(mean(x^2) + eps) * w``.

Grid tiles rows of ``x``; the feature dimension stays whole inside the
block (the reduction axis must be VMEM-resident), which is the standard
TPU layout for layernorm-family kernels.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid cell: keeps the block well under VMEM for any D we use.
ROW_BLOCK = 128


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(ms + eps)) * w_ref[...]


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x, w, eps: float = 1e-6):
    """RMS-normalize rows of x ([M, D]) with learned scale w ([D])."""
    m, d = x.shape
    bm = m if m <= ROW_BLOCK else ROW_BLOCK
    mp = -(-m // bm) * bm
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda mi: (mi, 0)),
            pl.BlockSpec((d,), lambda mi: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda mi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, d), jnp.float32),
        interpret=True,
    )(xp, w)
    return out[:m]
