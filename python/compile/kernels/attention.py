"""Single-step decode attention as a Pallas kernel.

Computes, for the current decode position ``pos``:

    out[b,h,:] = softmax(q[b,h,:] . K[b,h,t,:] / sqrt(dh), t <= pos) @ V

The grid iterates over heads; each grid cell holds the full (B, T, dh)
slice of one head's KV cache in VMEM plus the (B, dh) query block — the
TPU analogue of a flash-decoding split-KV tile (for our cache sizes one
tile covers the whole T axis; the BlockSpec generalizes to tiling T when
T*dh exceeds VMEM).  Masking uses an iota over T against the ``pos``
scalar, carried in as a (1,) array block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, t_total):
    # blocks: q [B, 1, dh], k/v [B, 1, T, dh], pos (1,)
    q = q_ref[:, 0, :].astype(jnp.float32)            # [B, dh]
    k = k_ref[:, 0, :, :].astype(jnp.float32)         # [B, T, dh]
    v = v_ref[:, 0, :, :].astype(jnp.float32)         # [B, T, dh]
    pos = pos_ref[0]

    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    # [B, T] scores via batched dot; lax.dot_general over the dh axis.
    scores = jax.lax.dot_general(
        q, k, (((1,), (2,)), ((0,), (0,)))) * scale
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (1, t_total), 1)
    scores = jnp.where(t_idx <= pos, scores, jnp.float32(-1e30))

    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jax.lax.dot_general(p, v, (((1,), (1,)), ((0,), (0,))))
    o_ref[:, 0, :] = out


@jax.jit
def attention_decode(q, k, v, pos):
    """Masked decode attention against a KV cache.

    q:   [B, H, dh]    current-step queries
    k,v: [B, H, T, dh] KV cache
    pos: i32 scalar    current position; positions > pos are masked out
    """
    b, h, dh = q.shape
    _, _, t, _ = k.shape
    pos_arr = jnp.reshape(pos.astype(jnp.int32), (1,))

    return pl.pallas_call(
        functools.partial(_attn_kernel, t_total=t),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda hi: (0,)),
            pl.BlockSpec((b, 1, dh), lambda hi: (0, hi, 0)),
            pl.BlockSpec((b, 1, t, dh), lambda hi: (0, hi, 0, 0)),
            pl.BlockSpec((b, 1, t, dh), lambda hi: (0, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, 1, dh), lambda hi: (0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=True,
    )(pos_arr, q, k, v)
