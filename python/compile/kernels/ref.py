"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package has a
reference implementation here, and ``python/tests`` asserts allclose between
kernel and reference across shape/dtype sweeps (hypothesis).  The references
are also used by the model tests as an end-to-end oracle.
"""

import jax.numpy as jnp


def apply_activation(x, act: str):
    """Activation menu shared by kernel and reference."""
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "gelu":
        # tanh approximation, matches jax.nn.gelu(approximate=True)
        c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))
    if act == "silu":
        return x * (1.0 / (1.0 + jnp.exp(-x)))
    raise ValueError(f"unknown activation {act!r}")


def fused_linear_ref(x, w, b=None, act: str = "none"):
    """Reference for kernels.fused_linear: act(x @ w + b)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    return apply_activation(y, act).astype(x.dtype)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    """Reference for kernels.rmsnorm: x * rsqrt(mean(x^2) + eps) * w."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax_rsqrt(ms + eps) * w).astype(x.dtype)


def jax_rsqrt(x):
    return 1.0 / jnp.sqrt(x)


def attention_decode_ref(q, k, v, pos):
    """Reference for kernels.attention_decode.

    q:   [B, H, dh]      query for the current step
    k,v: [B, H, T, dh]   KV cache (only positions < pos+1 are valid)
    pos: i32 scalar      index of the current step (attends to 0..=pos)
    """
    B, H, T, dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    scores = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    t_idx = jnp.arange(T)[None, None, :]
    mask = t_idx <= pos
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bht,bhtd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
