"""Layer-1 Pallas kernels for sincere-rs.

Every kernel is authored as a TPU-shaped Pallas kernel (BlockSpec tiling for
VMEM, MXU-sized blocks) but lowered with ``interpret=True`` so the resulting
HLO runs on the CPU PJRT client the Rust coordinator embeds.  Real-TPU
performance is estimated analytically from the BlockSpecs (DESIGN.md §Perf).
"""

from .fused_linear import fused_linear, matmul_block_shapes
from .rmsnorm import rmsnorm
from .attention import attention_decode

__all__ = [
    "fused_linear",
    "matmul_block_shapes",
    "rmsnorm",
    "attention_decode",
]
