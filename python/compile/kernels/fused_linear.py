"""Fused linear kernel: ``act(x @ w [+ b])`` as a tiled Pallas matmul.

This is the inference hot spot — every projection in the transformer
(QKV, attention output, gate/up/down MLP) goes through this kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles
(M, N) output blocks with the K dimension as the innermost grid axis;
each (bm, bk) x (bk, bn) block pair is MXU-shaped (<=128 per side) and
lives in VMEM while a float32 accumulator is kept in the output block
across K steps.  This is the TPU counterpart of the CUTLASS threadblock
tiling an H100 deployment would use.  Lowered with ``interpret=True``
for CPU-PJRT execution; VMEM/MXU numbers are estimated analytically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import apply_activation

# MXU systolic array side: blocks are capped at this in every dimension.
MXU_DIM = 128


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def matmul_block_shapes(m: int, k: int, n: int,
                        max_block: int = MXU_DIM) -> tuple[int, int, int]:
    """Pick (bm, bk, bn) block shapes for an (m, k) x (k, n) matmul.

    Blocks are the full dimension when it fits below ``max_block`` (so tiny
    decode matmuls stay a single grid cell), otherwise the MXU dimension.
    Dimensions must divide evenly; callers pad to multiples of the block.
    """
    bm = m if m <= max_block else max_block
    bk = k if k <= max_block else max_block
    bn = n if n <= max_block else max_block
    return bm, bk, bn


def vmem_bytes(bm: int, bk: int, bn: int, itemsize: int = 4) -> int:
    """Analytic VMEM footprint of one grid cell (x, w, out blocks)."""
    return itemsize * (bm * bk + bk * bn + bm * bn)


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, act, k_steps,
                         has_bias):
    """Grid (M/bm, N/bn, K/bk), K innermost; accumulate f32 into o_ref."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(ki == k_steps - 1)
    def _finish():
        acc = o_ref[...]
        if has_bias:
            acc = acc + b_ref[...]
        o_ref[...] = apply_activation(acc, act)


@functools.partial(jax.jit, static_argnames=("act", "max_block"))
def fused_linear(x, w, b=None, act: str = "none", max_block: int = MXU_DIM):
    """``act(x @ w [+ b])`` via a tiled Pallas kernel.

    x: [M, K] float32, w: [K, N] float32, b: optional [N] float32.
    M, K, N need not be multiples of the block size; inputs are zero-padded
    to block multiples and the result is sliced back (zero padding is exact
    for matmul + bias + the supported activations at padded rows/cols we
    discard).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    bm, bk, bn = matmul_block_shapes(m, k, n, max_block)

    mp, kp, np_ = _ceil_div(m, bm) * bm, _ceil_div(k, bk) * bk, \
        _ceil_div(n, bn) * bn
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w

    has_bias = b is not None
    bp = (jnp.pad(b, (0, np_ - n)) if np_ != n else b) if has_bias \
        else jnp.zeros((np_,), jnp.float32)

    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(_fused_linear_kernel, act=act, k_steps=k_steps,
                          has_bias=has_bias),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((bn,), lambda mi, ni, ki: (ni,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)

    return out[:m, :n]
