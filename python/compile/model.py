"""Layer-2 JAX model: decoder-only transformer with in-graph greedy decode.

One lowered graph does everything the serving path needs for a batch:
sequential prefill over the prompt (teacher forcing) followed by greedy
decoding of ``decode_len`` tokens, with a KV cache carried through a
``lax.scan`` over time steps and a second ``lax.scan`` over the stacked
layer parameters.  Every projection runs through the Layer-1 Pallas
``fused_linear`` kernel; attention and RMSNorm are Pallas kernels too, so
the whole hot path lowers into a single compact HLO module the Rust
runtime compiles once per (family, batch size).

The exported entry point is :func:`generate`, taking the prompt first and
then the parameter arrays in :meth:`Family.param_shapes` order — this fixed
positional order is what the artifact manifest records for the Rust side.
"""

import functools

import jax
import jax.numpy as jnp

from .families import Family
from .kernels import attention_decode, fused_linear, rmsnorm

PARAM_NAMES = ("embed", "attn_norm", "wqkv", "wo", "mlp_norm",
               "w_gate", "w_up", "w_down", "final_norm", "unembed")


def _layer(fam: Family, x, layer_in, pos):
    """One transformer block at one time step.

    x: [B, D] residual stream; layer_in carries this layer's stacked
    parameters plus its KV cache slices [B, H, T, dh].
    """
    (attn_norm, wqkv, wo, mlp_norm, w_gate, w_up, w_down, kc, vc) = layer_in
    b = x.shape[0]
    h_heads, dh = fam.n_heads, fam.head_dim

    # --- attention ---
    h = rmsnorm(x, attn_norm)
    qkv = fused_linear(h, wqkv)                          # [B, 3D]
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, h_heads, dh)
    k_new = k_new.reshape(b, h_heads, 1, dh)
    v_new = v_new.reshape(b, h_heads, 1, dh)
    kc = jax.lax.dynamic_update_slice(kc, k_new, (0, 0, pos, 0))
    vc = jax.lax.dynamic_update_slice(vc, v_new, (0, 0, pos, 0))
    att = attention_decode(q, kc, vc, pos)               # [B, H, dh]
    x = x + fused_linear(att.reshape(b, h_heads * dh), wo)

    # --- gated MLP ---
    h2 = rmsnorm(x, mlp_norm)
    gate = fused_linear(h2, w_gate, act=fam.act)
    up = fused_linear(h2, w_up)
    x = x + fused_linear(gate * up, w_down)
    return x, kc, vc


def _step(fam: Family, params: dict, tokens, kcache, vcache, pos):
    """Run all layers for one time step.

    tokens: [B] i32; kcache/vcache: [L, B, H, T, dh]; pos: traced i32.
    Returns (logits [B, V], updated caches).
    """
    x = jnp.take(params["embed"], tokens, axis=0)        # [B, D]

    def body(x, inp):
        x, kc, vc = _layer(fam, x, inp, pos)
        return x, (kc, vc)

    stacked = (params["attn_norm"], params["wqkv"], params["wo"],
               params["mlp_norm"], params["w_gate"], params["w_up"],
               params["w_down"], kcache, vcache)
    x, (kcache, vcache) = jax.lax.scan(body, x, stacked)

    h = rmsnorm(x, params["final_norm"])
    logits = fused_linear(h, params["unembed"])          # [B, V]
    return logits, kcache, vcache


def generate(fam: Family, prompt, *param_arrays):
    """Prefill + greedy-decode ``fam.decode_len`` tokens.

    prompt: [B, prompt_len] i32 in [0, vocab).
    Returns a 1-tuple ``(tokens [B, decode_len] i32,)`` — lowered with
    return_tuple=True, so the Rust side unwraps a tuple literal.
    """
    assert len(param_arrays) == len(PARAM_NAMES), \
        f"want {len(PARAM_NAMES)} param arrays, got {len(param_arrays)}"
    params = dict(zip(PARAM_NAMES, param_arrays))
    b, s = prompt.shape
    assert s == fam.prompt_len, (s, fam.prompt_len)
    t_total = fam.cache_len
    l, hh, dh = fam.n_layers, fam.n_heads, fam.head_dim

    kcache = jnp.zeros((l, b, hh, t_total, dh), jnp.float32)
    vcache = jnp.zeros_like(kcache)

    n_steps = s - 1 + fam.decode_len

    def body(carry, t):
        tok, kc, vc = carry
        logits, kc, vc = _step(fam, params, tok, kc, vc, t)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # teacher-force while still inside the prompt
        t_next = jnp.clip(t + 1, 0, s - 1)
        forced = jax.lax.dynamic_index_in_dim(prompt, t_next, axis=1,
                                              keepdims=False)
        next_tok = jnp.where(t + 1 < s, forced, pred)
        return (next_tok, kc, vc), pred

    init = (prompt[:, 0], kcache, vcache)
    _, preds = jax.lax.scan(body, init, jnp.arange(n_steps))
    # preds: [n_steps, B]; generated tokens start at step s-1.
    out = jnp.transpose(preds[s - 1:], (1, 0))           # [B, decode_len]
    return (out,)


def make_generate_fn(fam: Family):
    """Positional-arg closure suitable for jax.jit().lower()."""
    return functools.partial(generate, fam)


def reference_generate(fam: Family, params: dict, prompt):
    """Slow pure-jnp oracle of generate() for tests: same prefill+decode
    loop but using the ref kernels (no Pallas), written independently."""
    import numpy as np

    from .kernels import ref

    b, s = prompt.shape
    t_total = fam.cache_len
    l, hh, dh = fam.n_layers, fam.n_heads, fam.head_dim
    kc = np.zeros((l, b, hh, t_total, dh), np.float32)
    vc = np.zeros_like(kc)
    tok = np.asarray(prompt[:, 0])
    preds = []
    for t in range(s - 1 + fam.decode_len):
        x = np.asarray(params["embed"])[tok]
        for li in range(l):
            h = ref.rmsnorm_ref(jnp.asarray(x),
                                jnp.asarray(params["attn_norm"][li]))
            qkv = ref.fused_linear_ref(h, jnp.asarray(params["wqkv"][li]))
            q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
            kc[li, :, :, t, :] = np.asarray(k_new).reshape(b, hh, dh)
            vc[li, :, :, t, :] = np.asarray(v_new).reshape(b, hh, dh)
            att = ref.attention_decode_ref(
                jnp.asarray(np.asarray(q).reshape(b, hh, dh)),
                jnp.asarray(kc[li]), jnp.asarray(vc[li]), t)
            x = x + np.asarray(ref.fused_linear_ref(
                jnp.asarray(np.asarray(att).reshape(b, hh * dh)),
                jnp.asarray(params["wo"][li])))
            h2 = ref.rmsnorm_ref(jnp.asarray(x),
                                 jnp.asarray(params["mlp_norm"][li]))
            gate = ref.fused_linear_ref(h2, jnp.asarray(params["w_gate"][li]),
                                        act=fam.act)
            up = ref.fused_linear_ref(h2, jnp.asarray(params["w_up"][li]))
            x = x + np.asarray(ref.fused_linear_ref(
                gate * up, jnp.asarray(params["w_down"][li])))
        hfin = ref.rmsnorm_ref(jnp.asarray(x),
                               jnp.asarray(params["final_norm"]))
        logits = ref.fused_linear_ref(hfin, jnp.asarray(params["unembed"]))
        pred = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        preds.append(pred)
        if t + 1 < s:
            tok = np.asarray(prompt[:, t + 1])
        else:
            tok = pred
    preds = np.stack(preds, axis=1)                       # [B, n_steps]
    return preds[:, s - 1:]
