"""Model families — the Table II analogues.

The paper serves Llama-3.1-8B (16.07 GB), gemma-7b (17.07 GB) and
granite-7b-base (26.98 GB).  We build three architecturally distinct tiny
decoder-only transformers whose *relative* weight sizes preserve the paper's
ordering (granite >> gemma > llama, with gemma only slightly above llama) —
the scheduler only ever observes (bytes to load, load time, per-batch
inference time, OBS), so preserving the heterogeneity preserves the
scheduling problem.  ``paper_gb`` is carried into the artifact manifest so
the Rust DMA layer can optionally scale transfer *times* to paper-sized
models.
"""

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Family:
    """Architecture + provenance of one servable model family."""

    name: str            # our identifier, e.g. "llama-sim"
    hf_name: str         # the paper's Hugging Face model it stands in for
    paper_gb: float      # the paper's on-disk size (Table II)
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    act: str             # MLP gate activation: "silu" | "gelu"
    prompt_len: int = 16
    decode_len: int = 50
    seed: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def cache_len(self) -> int:
        """KV-cache length: prompt plus every generated token."""
        return self.prompt_len + self.decode_len

    def param_shapes(self) -> list[tuple[str, tuple[int, ...]]]:
        """Ordered (name, shape) list — the HLO parameter order after the
        prompt, and the layout of the flat weights .bin file."""
        d, l, f, v = self.d_model, self.n_layers, self.d_ff, self.vocab
        return [
            ("embed", (v, d)),
            ("attn_norm", (l, d)),
            ("wqkv", (l, d, 3 * d)),
            ("wo", (l, d, d)),
            ("mlp_norm", (l, d)),
            ("w_gate", (l, d, f)),
            ("w_up", (l, d, f)),
            ("w_down", (l, f, d)),
            ("final_norm", (d,)),
            ("unembed", (d, v)),
        ]

    def param_count(self) -> int:
        return sum(int(np.prod(s)) for _, s in self.param_shapes())

    def weight_bytes(self) -> int:
        return 4 * self.param_count()

    def kv_bytes_per_seq(self) -> int:
        """f32 KV-cache bytes for ONE sequence (both K and V, all layers).

        Drives the simulated-HBM memory model on the Rust side: device
        memory for a batch B is weight_bytes + B * kv_bytes_per_seq +
        activation headroom.
        """
        return 2 * 4 * self.n_layers * self.n_heads * self.cache_len \
            * self.head_dim

    def init_params(self) -> dict[str, np.ndarray]:
        """Deterministic weights: normals scaled 0.02, norms all-ones."""
        rng = np.random.RandomState(self.seed ^ _stable_hash(self.name))
        params = {}
        for name, shape in self.param_shapes():
            if name.endswith("norm"):
                params[name] = np.ones(shape, np.float32)
            else:
                params[name] = (rng.randn(*shape) * 0.02).astype(np.float32)
        return params


def _stable_hash(s: str) -> int:
    h = 2166136261
    for c in s.encode():
        h = ((h ^ c) * 16777619) & 0x7FFFFFFF
    return h


#: The serving fleet, mirroring Table II.  Weight bytes (f32):
#:   llama-sim   ~3.6 MB   <-  Llama-3.1-8B  16.07 GB
#:   gemma-sim   ~5.1 MB   <-  gemma-7b      17.07 GB
#:   granite-sim ~11.9 MB  <-  granite-7b    26.98 GB
FAMILIES: tuple[Family, ...] = (
    Family(name="llama-sim", hf_name="Llama-3.1-8B", paper_gb=16.07,
           d_model=128, n_layers=4, n_heads=4, d_ff=352, vocab=512,
           act="silu"),
    Family(name="gemma-sim", hf_name="gemma-7b", paper_gb=17.07,
           d_model=128, n_layers=4, n_heads=4, d_ff=512, vocab=768,
           act="gelu"),
    Family(name="granite-sim", hf_name="granite-7b-base", paper_gb=26.98,
           d_model=192, n_layers=6, n_heads=6, d_ff=512, vocab=768,
           act="silu"),
)


def by_name(name: str) -> Family:
    for f in FAMILIES:
        if f.name == name:
            return f
    raise KeyError(f"unknown family {name!r}; have "
                   f"{[f.name for f in FAMILIES]}")
