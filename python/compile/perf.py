"""L1/L2 performance analysis (DESIGN.md §Perf).

Because Pallas runs under ``interpret=True`` on CPU (real Mosaic
lowering needs a TPU), kernel performance is assessed *structurally*:

* L1 — per-kernel VMEM footprint and MXU utilization estimates derived
  from the BlockSpecs that would drive a real TPU lowering;
* L2 — HLO statistics of the lowered modules (op histogram, scan vs
  unroll check, parameter traffic) plus per-step FLOP counts and
  arithmetic intensity against the weights.

Usage: ``python -m compile.perf [--out report.md]`` (run from python/).
"""

import argparse
import re
import sys

from .families import FAMILIES, Family
from .kernels.fused_linear import matmul_block_shapes, vmem_bytes, MXU_DIM
from .model import PARAM_NAMES  # noqa: F401  (documented param order)

VMEM_BUDGET = 16 * 1024 * 1024  # bytes per TensorCore
MXU_FLOPS_PER_CYCLE = 2 * MXU_DIM * MXU_DIM  # one 128x128 MAC wave


def matmul_report(name: str, m: int, k: int, n: int) -> dict:
    """Blocking + utilization estimate for one fused_linear call."""
    bm, bk, bn = matmul_block_shapes(m, k, n)
    grid = (-(-m // bm), -(-n // bn), -(-k // bk))
    vmem = vmem_bytes(bm, bk, bn)
    # MXU utilization of one block-matmul wave: fraction of the 128x128
    # systolic array the block actually covers.
    mxu_util = (min(bm, MXU_DIM) * min(bn, MXU_DIM)) / (MXU_DIM * MXU_DIM)
    flops = 2 * m * k * n
    return {
        "name": name,
        "shape": f"({m}x{k})@({k}x{n})",
        "blocks": f"bm={bm} bk={bk} bn={bn}",
        "grid": grid,
        "vmem_bytes": vmem,
        "vmem_ok": vmem <= VMEM_BUDGET,
        "mxu_util": mxu_util,
        "flops": flops,
    }


def family_step_matmuls(fam: Family, batch: int) -> list[dict]:
    """All fused_linear calls in ONE decode step (per layer + head)."""
    d, f, v = fam.d_model, fam.d_ff, fam.vocab
    per_layer = [
        matmul_report("wqkv", batch, d, 3 * d),
        matmul_report("wo", batch, d, d),
        matmul_report("w_gate", batch, d, f),
        matmul_report("w_up", batch, d, f),
        matmul_report("w_down", batch, f, d),
    ]
    return per_layer + [matmul_report("unembed", batch, d, v)]


def family_flops(fam: Family, batch: int) -> float:
    """Total FLOPs for one generate() call (prefill + decode)."""
    steps = fam.prompt_len - 1 + fam.decode_len
    per_step = sum(r["flops"] for r in family_step_matmuls(fam, batch)[:-1]
                   ) * fam.n_layers \
        + family_step_matmuls(fam, batch)[-1]["flops"]
    # attention: q.K^T and p.V per layer, T = cache_len
    attn = 2 * 2 * batch * fam.n_heads * fam.cache_len * fam.head_dim \
        * fam.n_layers
    return steps * (per_step + attn)


def hlo_stats(text: str) -> dict:
    """Cheap structural statistics over an HLO text module."""
    ops = []
    for line in text.splitlines():
        if " = " not in line:
            continue
        # the opcode is the first bare identifier directly before a '('
        # after the '=' (types like (s32[], ...) start with '(', not a
        # letter, so they don't match)
        m = re.search(r"([a-z][a-z0-9-]*)\(", line.split(" = ", 1)[1])
        if m:
            ops.append(m.group(1))
    hist: dict[str, int] = {}
    for op in ops:
        hist[op] = hist.get(op, 0) + 1
    return {
        "total_instructions": len(ops),
        "while_loops": hist.get("while", 0),
        "dots": hist.get("dot", 0),
        "dynamic_slices": hist.get("dynamic-slice", 0),
        "top": sorted(hist.items(), key=lambda kv: -kv[1])[:8],
    }


def render(artifacts_dir: str | None) -> str:
    out = ["# L1/L2 performance analysis (analytic)\n"]

    out.append("## L1 — Pallas kernel blocking (batch = OBS-scale 16)\n")
    out.append("| family | kernel | shape | blocks | grid | VMEM | "
               "fits 16MiB | MXU util |")
    out.append("|---|---|---|---|---|---|---|---|")
    for fam in FAMILIES:
        for r in family_step_matmuls(fam, 16):
            out.append(
                f"| {fam.name} | {r['name']} | {r['shape']} | "
                f"{r['blocks']} | {r['grid']} | "
                f"{r['vmem_bytes'] / 1024:.0f} KiB | "
                f"{'yes' if r['vmem_ok'] else 'NO'} | "
                f"{r['mxu_util'] * 100:.0f}% |")
    out.append("")
    out.append(
        "MXU utilization below 100% reflects batch rows (< 128) — the\n"
        "decode-step GEMMs are inherently skinny; a real deployment would\n"
        "co-schedule batches (as the coordinator does) to fill rows.\n")

    out.append("## L2 — per-call FLOPs and weight arithmetic intensity\n")
    out.append("| family | batch | GFLOP/call | bytes(weights) | "
               "intensity (flops/byte) |")
    out.append("|---|---|---|---|---|")
    for fam in FAMILIES:
        for b in (1, 16, 32):
            fl = family_flops(fam, b)
            wb = fam.weight_bytes()
            out.append(f"| {fam.name} | {b} | {fl / 1e9:.2f} | "
                       f"{wb / 1e6:.1f} MB | {fl / wb:.0f} |")
    out.append("")

    if artifacts_dir:
        import os
        out.append("## L2 — lowered HLO structure\n")
        out.append("| artifact | instructions | while | dot | "
                   "dynamic-slice |")
        out.append("|---|---|---|---|---|")
        for fam in FAMILIES:
            path = os.path.join(artifacts_dir, f"{fam.name}_b16.hlo.txt")
            if not os.path.exists(path):
                continue
            st = hlo_stats(open(path).read())
            out.append(f"| {fam.name}_b16 | {st['total_instructions']} | "
                       f"{st['while_loops']} | {st['dots']} | "
                       f"{st['dynamic_slices']} |")
        out.append("")
        out.append(
            "`while` counts confirm scan-based decode (layers + time are\n"
            "rolled loops, not 50x unrolled graphs); instruction counts in\n"
            "the hundreds keep XLA compile times ~1s per artifact.\n")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    text = render(args.artifacts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
