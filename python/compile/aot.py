"""AOT lowering: JAX/Pallas -> HLO text + weights + manifest.

``python -m compile.aot --out ../artifacts`` emits, per model family:

  <family>_b<B>.hlo.txt   one HLO module per batch size (prefill+decode)
  <family>.weights.bin    flat f32 little-endian weight blob
and a single ``manifest.json`` describing parameter order/shapes/offsets,
batch sizes, token geometry and Table II provenance — everything the Rust
runtime needs to compile and feed the executables.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
``xla`` crate links xla_extension 0.5.1 which rejects jax>=0.5 protos
(64-bit instruction ids); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .families import FAMILIES, Family, by_name
from .model import make_generate_fn

DEFAULT_BATCH_SIZES = (1, 2, 4, 8, 16, 32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_family(fam: Family, batch: int) -> str:
    """Lower generate() for one (family, batch size) to HLO text."""
    prompt_spec = jax.ShapeDtypeStruct((batch, fam.prompt_len), jnp.int32)
    param_specs = [jax.ShapeDtypeStruct(shape, jnp.float32)
                   for _, shape in fam.param_shapes()]
    lowered = jax.jit(make_generate_fn(fam)).lower(prompt_spec, *param_specs)
    return to_hlo_text(lowered)


def write_weights(fam: Family, out_dir: str) -> dict:
    """Write the flat weight blob; return the manifest params entry."""
    params = fam.init_params()
    entries, blobs, offset = [], [], 0
    for name, shape in fam.param_shapes():
        arr = params[name]
        assert arr.shape == shape and arr.dtype == np.float32
        raw = arr.tobytes()  # C-order little-endian f32
        entries.append({
            "name": name,
            "shape": list(shape),
            "offset_bytes": offset,
            "size_bytes": len(raw),
        })
        blobs.append(raw)
        offset += len(raw)
    blob = b"".join(blobs)
    path = os.path.join(out_dir, f"{fam.name}.weights.bin")
    with open(path, "wb") as f:
        f.write(blob)
    return {
        "file": os.path.basename(path),
        "total_bytes": len(blob),
        "sha256": hashlib.sha256(blob).hexdigest(),
        "params": entries,
    }


def build(out_dir: str, families: list[Family],
          batch_sizes: tuple[int, ...]) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format_version": 1,
        "batch_sizes": list(batch_sizes),
        "families": [],
    }
    for fam in families:
        print(f"[aot] {fam.name}: weights "
              f"({fam.weight_bytes() / 1e6:.2f} MB) ...", flush=True)
        weights = write_weights(fam, out_dir)
        artifacts = {}
        for b in batch_sizes:
            hlo = lower_family(fam, b)
            fname = f"{fam.name}_b{b}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(hlo)
            artifacts[str(b)] = fname
            print(f"[aot]   b={b:<3d} -> {fname} "
                  f"({len(hlo) / 1e3:.0f} kB hlo)", flush=True)
        manifest["families"].append({
            "name": fam.name,
            "hf_name": fam.hf_name,
            "paper_gb": fam.paper_gb,
            "d_model": fam.d_model,
            "n_layers": fam.n_layers,
            "n_heads": fam.n_heads,
            "d_ff": fam.d_ff,
            "vocab": fam.vocab,
            "act": fam.act,
            "prompt_len": fam.prompt_len,
            "decode_len": fam.decode_len,
            "cache_len": fam.cache_len,
            "kv_bytes_per_seq": fam.kv_bytes_per_seq(),
            "param_count": fam.param_count(),
            "weights": weights,
            "artifacts": artifacts,
        })
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for artifacts")
    ap.add_argument("--families", default="all",
                    help="comma-separated family names, or 'all'")
    ap.add_argument("--batch-sizes",
                    default=",".join(str(b) for b in DEFAULT_BATCH_SIZES))
    args = ap.parse_args(argv)

    fams = list(FAMILIES) if args.families == "all" else \
        [by_name(n) for n in args.families.split(",")]
    batch_sizes = tuple(int(b) for b in args.batch_sizes.split(","))

    manifest = build(args.out, fams, batch_sizes)
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {mpath}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
