"""Build-time compile path for sincere-rs (Layer 1 + Layer 2).

Python in this package runs ONLY at build time (``make artifacts``): it
authors the Pallas kernels and the JAX transformer, AOT-lowers them to HLO
text, and emits deterministic weights.  Nothing here is imported at serve
time — the Rust coordinator is self-contained once ``artifacts/`` exists.
"""
